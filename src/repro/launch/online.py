"""Online serving launcher: train -> stream -> serve, in one process.

    PYTHONPATH=src python -m repro.launch.online --dataset movielens100k \
        --scale 0.05 --train-epochs 3 --events 500 --swap-every 3 --clients 4

Runs the full freshness loop the online subsystem exists for:

1. train a DP-MF model (or resume from ``--ckpt``) on a train split;
2. start the serving engine + async request queue and hammer it from
   ``--clients`` concurrent request threads for the whole run;
3. stream held-out (or synthetic Poisson) events through the
   :class:`~repro.online.updater.OnlineUpdater` — pruned row updates only,
   each batch scored *prequentially* (test-then-learn, see
   :mod:`repro.eval.prequential`) before it is applied;
4. every ``--swap-every`` micro-batches, hot-swap the new factor version
   into the live engine (zero dropped requests) and write an async delta
   checkpoint.

Exit status is non-zero if ANY request failed or was dropped during the run
— the CI smoke contract.  ``--slo-p99-ms BUDGET`` additionally arms the
SLO-aware degradation loop (:mod:`repro.serving.slo`): the controller ticks
inside the update loop, adapts pruning thresholds to hold serving p99 under
the budget (pinning them through publishes), relaxes when the prequential
drift hook reports quality pressure, and the run exits non-zero if the
steady-state p99 still violates the budget.  A JSON report (throughput, swap latency, serving
percentiles, work fraction, prequential MAE/RMSE trajectory, MAE
before/after) lands on stdout and, with ``--json``, on disk.

With ``--replicas N`` (N > 1) the serving side becomes a fleet
(``repro.serving.fleet``): N replica engines behind the cache-aware
router, subscribed to the publisher's replication bus — every swap ships
a compressed versioned delta and applies it **rolling** (one replica at a
time), while the client threads keep hammering the router.  The same
zero-failed-requests exit contract holds, plus the report asserts every
replica converged to the published version.
"""
from __future__ import annotations

import argparse
import json
import threading
import time

import numpy as np

from repro.core.trainer import DPMFTrainer, TrainConfig
from repro.data.ratings import paper_dataset, train_test_split
from repro.eval import PrequentialEvaluator, recalibration_hook
from repro.online import (
    OnlineUpdater,
    PoissonSource,
    ReplaySource,
    SnapshotPublisher,
    iter_microbatches,
)
from repro.serving import (
    LatencyWindow,
    ServingEngine,
    SLOConfig,
    SLOController,
)


def run_online(args) -> dict:
    ds = paper_dataset(args.dataset, seed=args.seed, scale=args.scale)
    rest, test_ds = train_test_split(ds, 0.15, seed=args.seed)
    train_ds, stream_ds = train_test_split(rest, 0.25, seed=args.seed + 1)

    config = TrainConfig(
        k=args.k,
        epochs=args.train_epochs,
        batch_size=args.batch_size,
        lr=args.lr,
        pruning_rate=args.pruning_rate,
        variant=args.variant,
        seed=args.seed,
        checkpoint_dir=args.ckpt,
    )
    trainer = DPMFTrainer(config, train_ds, test_ds)
    if trainer.maybe_restore():
        print(f"# resumed training checkpoint at epoch {trainer.epoch}")
    trainer.run()
    mae_before = trainer.evaluate()
    print(f"# trained: MAE {mae_before:.4f}, t_q {float(trainer.t_q):.4f}")

    updater = OnlineUpdater.from_trainer(
        trainer, batch_size=max(args.batch_events, 64)
    )
    evictor = None
    if args.evict_max_users > 0:
        # Bounded user-table serving: cold rows spill to disk and the table
        # compacts at publish points; evicted users keep getting answers
        # through the engine's bias/popularity fallback.
        import tempfile

        from repro.store.eviction import EvictionConfig, UserEvictor

        spill_dir = (
            args.ckpt + "/spill" if args.ckpt
            else tempfile.mkdtemp(prefix="dpmf_spill_")
        )
        evictor = UserEvictor(EvictionConfig(
            max_users=args.evict_max_users,
            spill_dir=spill_dir,
            target_users=args.evict_target_users or None,
        ))
        updater.attach_evictor(evictor)
        print(f"# eviction armed: max {args.evict_max_users} rows, "
              f"target {evictor.config.resolved_target()}, spill {spill_dir}")
    engine_kwargs = dict(
        use_kernel=True if args.use_kernel else None,
        block_n=args.block_n,
    )
    fleet = None
    supervisor = None
    if args.replicas > 1:
        from repro.serving.fleet import ServingFleet

        fleet = ServingFleet(
            trainer.params, trainer.t_p, trainer.t_q,
            replicas=args.replicas,
            backend=args.replica_backend,
            user_history=trainer.hist,
            engine_kwargs=engine_kwargs,
            queue_kwargs={"linger_ms": 1.0},
            router_kwargs={"policy": args.routing},
        )
        frontend = fleet
        engine = None
        print(f"# fleet: {args.replicas} {args.replica_backend} replicas, "
              f"routing={args.routing}")
        if args.supervise:
            supervisor = fleet.supervise(
                probe_interval_s=0.5,
                checkpoint=(args.ckpt or None),
                online_dir=(args.ckpt + "/online") if args.ckpt else None,
            )
            print("# supervisor armed: probe 0.5s, auto-respawn on")
    else:
        engine = ServingEngine(
            trainer.params, trainer.t_p, trainer.t_q,
            user_history=trainer.hist, **engine_kwargs,
        )
        frontend = engine
    publisher = SnapshotPublisher(
        engine, updater,
        checkpoint_dir=(args.ckpt + "/online") if args.ckpt else None,
    )
    if fleet is not None:
        publisher.subscribe(fleet.router)

    if args.source == "replay":
        source = ReplaySource(stream_ds, epochs=None, shuffle=True,
                              seed=args.seed)
    else:
        source = PoissonSource(
            updater.num_users, updater.num_items,
            rate=1000.0, seed=args.seed,
            new_user_prob=args.new_id_prob, new_item_prob=args.new_id_prob,
            rating_min=ds.rating_min, rating_max=ds.rating_max,
        )

    queue = None
    if engine is not None:
        # warm the power-of-two buckets queue batches can land in, so the
        # first in-flight requests measure serving, not compiles
        warm_users = np.arange(min(engine.num_users, 8), dtype=np.int32)
        for b in (1, 2, 4, 8):
            if b <= len(warm_users):
                engine.topk(warm_users[:b], args.topk)
        queue = engine.start(linger_ms=1.0)

    # ---- SLO-aware degradation loop (off unless --slo-p99-ms > 0) ---------
    controller = None
    if args.slo_p99_ms > 0:
        slo_config = SLOConfig(
            p99_budget_ms=args.slo_p99_ms, max_rate=args.slo_max_rate
        )
        if engine is not None:
            # queue supplies all load signals: latency window, depth, expiry
            controller = SLOController(
                engine, config=slo_config, queue=queue, publisher=publisher
            )
        else:
            # process replicas own their queues; observe latency client-side
            controller = SLOController(
                config=slo_config, window=LatencyWindow(),
                router=fleet.router, publisher=publisher,
                params_fn=lambda: updater.params,
            )
        print(f"# slo: p99 budget {args.slo_p99_ms} ms, floor rate "
              f"{controller.floor_rate:.3f}, max rate {args.slo_max_rate}")

    # ---- concurrent request traffic over the whole stream window ----------
    num_users = frontend.num_users
    stop = threading.Event()
    latencies: list = []
    failures: list = []
    ok = [0]
    lock = threading.Lock()

    def client(seed: int) -> None:
        rng = np.random.default_rng(seed)
        while not stop.is_set():
            user = int(rng.integers(0, num_users))
            t0 = time.perf_counter()
            try:
                frontend.submit(user, args.topk, timeout=30.0).result(timeout=60)
                dt = time.perf_counter() - t0
                if controller is not None and controller.queue is None:
                    # fleet path: the queue lives in the replicas, so the
                    # controller's latency window is fed client-side
                    controller.window.record(dt)
                with lock:
                    ok[0] += 1
                    latencies.append(dt)
            except Exception as exc:  # noqa: BLE001 - any failure fails the run
                with lock:
                    failures.append(f"user {user}: {exc!r}")

    threads = [
        threading.Thread(target=client, args=(1000 + c,), daemon=True)
        for c in range(args.clients)
    ]
    for t in threads:
        t.start()

    # ---- the update loop: prequential test-then-learn ----------------------
    # every batch is scored by the pre-update model, THEN applied — the
    # running MAE/RMSE is an always-fresh accuracy estimate of the online
    # model, and the drift hook recalibrates off it (not a stale test set)
    evaluator = PrequentialEvaluator(
        updater, window=args.prequential_window
    )
    evaluator.add_drift_hook(
        recalibration_hook(updater, min_events=args.prequential_window)
    )
    if controller is not None:
        # quality guardrail: prequential drift makes the next tick relax
        evaluator.add_drift_hook(controller.quality_hook())
    swaps = []
    events = 0
    work_fractions = []
    eviction_rounds = []
    t_stream = time.perf_counter()
    for b, batch in enumerate(
        iter_microbatches(source, args.batch_events, max_events=args.events)
    ):
        metrics = evaluator.consume(batch)
        events += metrics["events"]
        work_fractions.append(metrics["work_fraction"])
        if controller is not None:
            controller.maybe_tick()
        if (b + 1) % args.swap_every == 0:
            info = updater.maybe_recalibrate()  # no-op within drift budget
            if info:
                print(f"# recalibrated: drift {info['drift']:.3f}")
            if evictor is not None:
                ev_info = evictor.maybe_evict()
                if ev_info:
                    eviction_rounds.append(ev_info)
                    print(f"# evicted {ev_info['evicted']} cold rows -> "
                          f"{ev_info['num_users']} live "
                          f"(remap epoch {ev_info['remap_epoch']})")
            swaps.append(publisher.publish())
    swaps.append(publisher.publish())  # final flush
    stream_s = time.perf_counter() - t_stream
    publisher.close()
    preq = evaluator.stats
    print(f"# prequential: MAE {preq.mae:.4f} (window {preq.window_mae:.4f},"
          f" ema {preq.ema_mae:.4f}) over {preq.events} events")

    stop.set()
    for t in threads:
        t.join(timeout=120)
    fleet_stats = None if fleet is None else fleet.stats()
    supervisor_report = None
    if supervisor is not None:
        supervisor.stop()
        supervisor_report = supervisor.report()
    if engine is not None:
        engine.stop()
    else:
        fleet.close()

    mae_after = updater.evaluate(test_ds)
    lat_ms = np.asarray(latencies) * 1e3 if latencies else np.zeros(1)
    report = {
        "events": events,
        "event_rate_per_s": events / max(stream_s, 1e-9),
        "mean_work_fraction": float(np.mean(work_fractions)),
        "swaps": len(swaps),
        "final_version": (
            engine.version if engine is not None else publisher.version
        ),
        "swap_ms_p50": float(np.percentile([s.swap_s * 1e3 for s in swaps], 50)),
        "swap_ms_max": float(max(s.swap_s * 1e3 for s in swaps)),
        "requests_ok": ok[0],
        "requests_failed": len(failures),
        "latency_ms_p50": float(np.percentile(lat_ms, 50)),
        "latency_ms_p99": float(np.percentile(lat_ms, 99)),
        "mae_before": mae_before,
        "mae_after": mae_after,
        "prequential": preq.as_dict(),
        "num_users": num_users,
        "num_items": updater.num_items,
    }
    if evictor is not None:
        report["eviction"] = {
            "rounds": len(eviction_rounds),
            "evicted_total": int(sum(e["evicted"] for e in eviction_rounds)),
            "spilled_resident": len(evictor.spilled_external_ids()),
            "remap_epoch": evictor.remap.epoch,
            "physical_users": int(updater.num_users),
            "external_users": int(evictor.remap.num_external),
        }
    if controller is not None:
        # steady-state view: the back half of completions, after the
        # controller has had the whole stream window to settle
        steady = lat_ms[len(lat_ms) // 2:]
        steady_p99 = float(np.percentile(steady, 99)) if steady.size else 0.0
        report["slo"] = controller.report()
        report["steady_p99_ms"] = steady_p99
        report["slo_violated"] = bool(steady_p99 > args.slo_p99_ms)
    if supervisor_report is not None:
        report["failures"] = supervisor_report
    if fleet_stats is not None:
        # unhealthy replicas report a stub stats dict without "version"
        replica_versions = {
            r["replica_id"]: r.get("version")
            for r in fleet_stats["replicas"]
        }
        stale = [
            rid for rid, v in replica_versions.items()
            if v is not None and v != publisher.version
        ]
        report.update({
            "replicas": args.replicas,
            "replica_backend": args.replica_backend,
            "routing": fleet_stats["policy"],
            "affinity_hits": fleet_stats["affinity_hits"],
            "replica_versions": replica_versions,
            "publisher_lag": publisher.lag(),
            "wire_bytes_total": int(sum(s.wire_bytes for s in swaps)),
            "wire_raw_bytes_total": int(sum(s.wire_raw_bytes for s in swaps)),
        })
        if stale:
            failures.append(
                f"replicas did not converge to v{publisher.version}: {stale}"
            )
            report["requests_failed"] = len(failures)
    if failures:
        report["failure_samples"] = failures[:5]
    return report


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--dataset", default="movielens100k",
                        choices=["movielens100k", "appliances",
                                 "bookcrossings", "jester"])
    parser.add_argument("--scale", type=float, default=0.05,
                        help="dataset size multiplier")
    parser.add_argument("--k", type=int, default=24)
    parser.add_argument("--train-epochs", type=int, default=3)
    parser.add_argument("--batch-size", type=int, default=1024,
                        help="offline training batch size")
    parser.add_argument("--lr", type=float, default=0.05)
    parser.add_argument("--pruning-rate", type=float, default=0.3)
    parser.add_argument("--variant", default="funk",
                        choices=["funk", "bias", "svdpp"])
    parser.add_argument("--events", type=int, default=500,
                        help="total streamed events")
    parser.add_argument("--batch-events", type=int, default=64,
                        help="events per update micro-batch")
    parser.add_argument("--swap-every", type=int, default=3,
                        help="hot-swap every N micro-batches")
    parser.add_argument("--source", default="replay",
                        choices=["replay", "poisson"])
    parser.add_argument("--prequential-window", type=int, default=256,
                        help="windowed prequential MAE/RMSE span (events)")
    parser.add_argument("--new-id-prob", type=float, default=0.02,
                        help="cold-start id probability (poisson source)")
    parser.add_argument("--clients", type=int, default=4,
                        help="concurrent request threads during the stream")
    parser.add_argument("--replicas", type=int, default=1,
                        help="serve through a fleet of N replica engines on "
                             "the replication bus (1 = single engine)")
    parser.add_argument("--replica-backend", choices=("local", "process"),
                        default="local",
                        help="fleet replicas in-process or as spawned "
                             "multiprocessing children")
    parser.add_argument("--supervise", action="store_true",
                        help="run a FleetSupervisor: heartbeat probes, "
                             "failover routing, auto-respawn of dead "
                             "replicas (requires --replicas > 1)")
    parser.add_argument("--routing", choices=("affinity", "least", "random"),
                        default="affinity",
                        help="fleet routing policy (see serving/fleet/router)")
    parser.add_argument("--topk", type=int, default=10)
    parser.add_argument("--block-n", type=int, default=1024)
    parser.add_argument("--use-kernel", action="store_true",
                        help="force the Pallas kernel path (default: TPU only)")
    parser.add_argument("--ckpt", default=None,
                        help="checkpoint dir (training + online deltas)")
    parser.add_argument("--evict-max-users", type=int, default=0,
                        help="cap the physical user table at N rows: cold "
                             "rows spill to disk and compact out at publish "
                             "points (0 = unbounded, eviction off)")
    parser.add_argument("--evict-target-users", type=int, default=0,
                        help="compaction target row count (0 = 80%% of "
                             "--evict-max-users)")
    parser.add_argument("--slo-p99-ms", type=float, default=0.0,
                        help="enable the SLO-aware pruning controller with "
                             "this p99 latency budget in ms (0 = off); the "
                             "run exits non-zero if the steady-state p99 "
                             "still violates the budget")
    parser.add_argument("--slo-max-rate", type=float, default=0.8,
                        help="ceiling on the controller's effective pruning "
                             "rate (the quality floor)")
    parser.add_argument("--seed", type=int, default=0)
    parser.add_argument("--json", default=None, metavar="PATH",
                        help="also write the run report to PATH")
    args = parser.parse_args()

    report = run_online(args)
    print(json.dumps(report, indent=2))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
    if report["requests_failed"]:
        raise SystemExit(
            f"{report['requests_failed']} requests failed during the run"
        )
    if report.get("slo_violated"):
        raise SystemExit(
            f"SLO violated: steady-state p99 {report['steady_p99_ms']:.2f} ms"
            f" > budget {args.slo_p99_ms:.2f} ms"
        )


if __name__ == "__main__":
    main()
