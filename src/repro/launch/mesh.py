"""Production mesh construction.

A function (never a module-level constant) so importing this module touches
no jax device state; the dry-run sets the 512-placeholder-device XLA flag
before jax initializes, and only then calls these.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when ``multi_pod``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False):
    """Tiny mesh with the same axis names for CI-scale SPMD tests (needs >= 8
    host devices via --xla_force_host_platform_device_count)."""
    shape = (2, 2, 2) if multi_pod else (2, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
