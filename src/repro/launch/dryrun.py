import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
# ^ The two lines above MUST stay first — before any other import — because
#   jax locks the device count at first initialization.

__doc__ = """Multi-pod dry-run: lower + compile every (arch x shape) cell on
the production meshes and record memory/cost/collective statistics.

    PYTHONPATH=src python -m repro.launch.dryrun --arch fm --shape train_batch
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Results are cached as JSON under benchmarks/results/dryrun/ keyed by
(arch, shape, mesh); EXPERIMENTS.md §Dry-run and §Roofline are generated from
these files.
"""

import argparse
import json
import time
import traceback

import jax

from repro import configs as cfg_lib
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.roofline import analysis

RESULTS_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "benchmarks", "results", "dryrun"
)


import dataclasses as _dc

# §Perf variants: config transforms applied to LM cells via --variant.
_VARIANTS = {
    # iteration 1 (MoE): shard_map replicated-dispatch EP (kills the MoE
    # dispatch all-reduces — see models/moe.moe_ffn_shard_map)
    "moe_sm": lambda cfg: _dc.replace(cfg, moe_shard_map=True),
    # iteration 2 (dense LM, memory): bf16 score/softmax chain
    "attn_bf16": lambda cfg: _dc.replace(cfg, attn_softmax_dtype="bf16"),
    # iteration 3 (dense LM, memory): keep matmul outputs, recompute the rest
    "remat_dots": lambda cfg: _dc.replace(cfg, remat_policy="dots"),
    # iteration 4 (dense LM, memory): lean norms + bf16 CE chain
    "mem_lean": lambda cfg: _dc.replace(cfg, mem_lean=True),
    # combined memory variant
    "mem_opt": lambda cfg: _dc.replace(
        cfg, attn_softmax_dtype="bf16", remat_policy="dots", mem_lean=True
    ),
    # MoE combined: shard_map dispatch + lean memory
    "moe_sm2": lambda cfg: _dc.replace(
        cfg, moe_shard_map=True, attn_softmax_dtype="bf16", mem_lean=True
    ),
}


def _variant_cfg(arch: str, variant: str):
    cfg = cfg_lib.get_module(arch).CONFIG
    return _VARIANTS[variant](cfg) if variant else cfg


def _calib_cell(arch: str, shape_id: str, depth: int, variant: str = ""):
    """Depth-override variant (unrolled python loop over layers) used for the
    two-point cost extrapolation: scan bodies are cost-analysed once per
    program, so we compile depth-(d+1) and depth-(d+2) unrolled variants and
    reconstruct  total = entry + L_scan * body  exactly (layers are
    homogeneous).  See roofline/analysis.extrapolate_depth."""
    import dataclasses

    from repro.configs import base as cfg_base

    cfg = _variant_cfg(arch, variant)
    new_cfg = dataclasses.replace(
        cfg, n_layers=cfg.first_dense_layers + depth, unroll=True
    )
    return cfg_base.lm_cells(arch, new_cfg)[shape_id]()


def run_cell(
    arch: str,
    shape_id: str,
    *,
    multi_pod: bool,
    debug: bool = False,
    calib_depth: int = 0,
    variant: str = "",
):
    """Lower + compile one cell; returns the result record dict."""
    mesh = (make_debug_mesh if debug else make_production_mesh)(multi_pod=multi_pod)
    if calib_depth or variant:
        from repro.configs import base as cfg_base

        cfg = _variant_cfg(arch, variant)
        if calib_depth:
            cell = _calib_cell(arch, shape_id, calib_depth, variant)
        else:
            cell = cfg_base.lm_cells(arch, cfg)[shape_id]()
    else:
        cell = cfg_lib.build_cell(arch, shape_id)
    record = {
        "arch": arch,
        "shape": shape_id,
        "kind": cell.kind,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "axes": list(mesh.axis_names),
        "note": cell.note,
        "variant": variant,
    }
    t0 = time.time()
    from repro.distributed.sharding import sanitize_shardings

    in_sh = sanitize_shardings(cell.in_shardings(mesh), cell.abstract_args)
    # use_mesh (not a bare `with mesh:`) so shard_map variants can resolve
    # the ambient mesh at trace time on every jax version.
    from repro.distributed.mesh_compat import use_mesh

    with use_mesh(mesh):
        jitted = jax.jit(
            cell.step_fn,
            in_shardings=in_sh,
            donate_argnums=cell.donate_argnums,
        )
        lowered = jitted.lower(*cell.abstract_args)
        record["lower_s"] = round(time.time() - t0, 2)

        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 2)

    # --- memory ----------------------------------------------------------
    try:
        mem = compiled.memory_analysis()
        record["memory"] = {
            "argument_size_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_size_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_size_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_size_bytes": int(
                getattr(mem, "generated_code_size_in_bytes", 0)
            ),
        }
    except Exception as exc:  # CPU backend may not expose everything
        record["memory"] = {"error": repr(exc)}

    # --- cost ------------------------------------------------------------
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        record["cost"] = {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "transcendentals": float(cost.get("transcendentals", 0.0)),
        }
    except Exception as exc:
        record["cost"] = {"error": repr(exc)}

    # --- collectives (parsed from the partitioned HLO) --------------------
    try:
        hlo = compiled.as_text()
        record["collectives"] = analysis.collective_bytes(hlo)
        record["hlo_ops"] = analysis.op_histogram(hlo)
    except Exception as exc:
        record["collectives"] = {"error": repr(exc)}

    return record


def result_path(
    arch: str, shape_id: str, multi_pod: bool, calib_depth: int = 0,
    variant: str = "",
) -> str:
    tag = "multipod" if multi_pod else "singlepod"
    if variant:
        tag += f"__v-{variant}"
    if calib_depth:
        tag += f"__calib{calib_depth}"
    safe = arch.replace("/", "_").replace(".", "_")
    return os.path.abspath(
        os.path.join(RESULTS_DIR, f"{safe}__{shape_id}__{tag}.json")
    )


def _is_lm_arch(arch: str) -> bool:
    from repro.models.transformer import TransformerConfig

    return isinstance(cfg_lib.get_module(arch).CONFIG, TransformerConfig)


def main() -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--arch", default=None)
    parser.add_argument("--shape", default=None)
    parser.add_argument("--all", action="store_true")
    parser.add_argument(
        "--mesh", choices=["single", "multi", "both"], default="both"
    )
    parser.add_argument("--debug-mesh", action="store_true",
                        help="2x2(x2) mesh for fast checks")
    parser.add_argument("--force", action="store_true", help="ignore cache")
    parser.add_argument("--include-dpmf", action="store_true", default=True)
    parser.add_argument("--variant", default="",
                        choices=[""] + sorted(_VARIANTS),
                        help="apply a §Perf config variant to LM cells")
    parser.add_argument(
        "--calib",
        action="store_true",
        help="also compile unrolled depth-1/2 variants of LM cells for exact "
        "cost extrapolation (roofline)",
    )
    args = parser.parse_args()

    if args.all:
        targets = cfg_lib.all_cells(include_dpmf=args.include_dpmf)
    elif args.arch and args.shape:
        targets = [(args.arch, args.shape)]
    elif args.arch:
        targets = [(args.arch, sid) for sid in cfg_lib.shape_ids(args.arch)]
    else:
        parser.error("pass --all or --arch [--shape]")

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    os.makedirs(RESULTS_DIR, exist_ok=True)

    failures = 0
    runs = []
    for arch, shape_id in targets:
        for multi_pod in meshes:
            runs.append((arch, shape_id, multi_pod, 0))
            if args.calib and _is_lm_arch(arch):
                runs.append((arch, shape_id, multi_pod, 1))
                runs.append((arch, shape_id, multi_pod, 2))

    for arch, shape_id, multi_pod, depth in runs:
        path = result_path(arch, shape_id, multi_pod, depth, args.variant)
        tag = f"{arch}::{shape_id} multi_pod={multi_pod}" + (
            f" calib={depth}" if depth else ""
        ) + (f" variant={args.variant}" if args.variant else "")
        if not args.force and os.path.exists(path) and not args.debug_mesh:
            print(f"[cached] {tag}")
            continue
        print(f"[run]    {tag}", flush=True)
        try:
            record = run_cell(
                arch,
                shape_id,
                multi_pod=multi_pod,
                debug=args.debug_mesh,
                calib_depth=depth,
                variant=args.variant,
            )
            record["status"] = "ok"
            record["calib_depth"] = depth
        except Exception as exc:  # noqa: BLE001 — report and continue
            failures += 1
            record = {
                "arch": arch,
                "shape": shape_id,
                "mesh": "multi" if multi_pod else "single",
                "status": "error",
                "calib_depth": depth,
                "error": repr(exc),
                "traceback": traceback.format_exc(),
            }
            print(f"[FAIL]   {tag}: {exc!r}", flush=True)
        if not args.debug_mesh:
            with open(path, "w") as f:
                json.dump(record, f, indent=2)
        if record["status"] == "ok":
            flops = record.get("cost", {}).get("flops", 0)
            coll = record.get("collectives", {}).get("total_bytes", 0)
            print(
                f"[ok]     {tag} lower={record['lower_s']}s "
                f"compile={record['compile_s']}s flops={flops:.3e} "
                f"coll_bytes={coll:.3e}",
                flush=True,
            )
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
