"""Serving launcher: batched top-k recommendation from a trained DP-MF
checkpoint through the serving engine (``repro.serving``).

    PYTHONPATH=src python -m repro.launch.serve --ckpt /tmp/dpmf_ckpt \
        --users 0 1 2 --topk 10

The engine restores the FULL ``MFParams`` (biases and SVD++ implicit factors
included — not just ``p``/``q``), precomputes the per-item ranks and tiled
factor layout once at load, and answers requests through the streaming
pruned top-k path (Pallas kernel on TPU, ``lax.top_k``-merge scan on CPU)
without ever materializing the (B, n) score matrix.

Traffic modes on top of the one-shot lookup:

* ``--batched-requests N`` — one synchronous N-user batch (PR-1 behaviour);
* ``--concurrent N --clients C`` — N single-user requests from C client
  threads through the async request queue (``serving/queue.py``): continuous
  batching, deadline scheduling, per-request timeout; reports latency
  percentiles and throughput;
* ``--http PORT`` — a minimal event-loop server: every connection submits to
  the queue and blocks on its future, so concurrent HTTP clients coalesce
  into shared scoring launches.  ``GET /recommend?user=3&topk=10``.

With ``--replicas N`` (N > 1) the same traffic modes run against a serving
*fleet* instead of a single engine: N replica engines
(``--replica-backend local`` in-process, ``process`` as spawned children)
behind the cache-aware router (``repro.serving.fleet``), so ``--http``
becomes the router's HTTP frontend and ``--concurrent`` measures routed
throughput.  ``--routing`` selects the policy (affinity/least/random).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.serving import (
    QueueFullError,
    RequestTimeout,
    ServingEngine,
    load_mf_checkpoint,
)


def _shutdown(frontend) -> None:
    """Graceful drain for either frontend kind: ``ServingEngine.stop`` or
    ``ServingFleet.close`` — both complete in-flight requests first."""
    if isinstance(frontend, ServingEngine):
        frontend.stop()
    else:
        frontend.close()


def run_concurrent(frontend, n_requests: int, clients: int,
                   topk: int, timeout: float) -> None:
    """Drive the async frontend (one engine, or a routed fleet) from
    ``clients`` submitter threads."""
    from concurrent.futures import ThreadPoolExecutor

    queue = None
    rng = np.random.default_rng(0)
    users = rng.integers(0, frontend.num_users, n_requests)
    if isinstance(frontend, ServingEngine):
        queue = frontend.start(linger_ms=1.0,
                               max_pending=max(1024, n_requests))
        # warm every power-of-two bucket a batch can land in
        for b in (1, 2, 4, 8, 16, 32, 64):
            if b <= min(frontend.max_batch, n_requests):
                frontend.topk(users[:b], topk)

    latencies = np.empty(n_requests)

    def client(i_u):
        i, u = i_u
        t0 = time.perf_counter()
        frontend.submit(int(u), topk, timeout=timeout).result(timeout=timeout)
        latencies[i] = time.perf_counter() - t0

    start = time.perf_counter()
    with ThreadPoolExecutor(max_workers=clients) as pool:
        list(pool.map(client, enumerate(users)))
    wall = time.perf_counter() - start
    stats = None if queue is not None else frontend.stats()
    _shutdown(frontend)
    p50, p99 = np.percentile(latencies * 1e3, [50, 99])
    line = (f"concurrent: {n_requests} requests, {clients} clients in "
            f"{wall:.3f}s ({n_requests / wall:.1f} req/s; p50 {p50:.2f} ms, "
            f"p99 {p99:.2f} ms")
    if queue is not None:
        line += (f"; {queue.batches_served} launches, mean batch "
                 f"{queue.requests_served / queue.batches_served:.1f})")
    else:
        line += (f"; routed over {len(stats['replicas'])} replicas, "
                 f"policy={stats['policy']}, "
                 f"affinity hits {stats['affinity_hits']})")
    print(line)


def run_http(frontend, port: int, topk_default: int,
             timeout: float) -> None:
    """Blocking HTTP front end over the async queue — or, for a fleet, over
    the router (stdlib only).  Shutdown drains: in-flight requests complete
    before the process exits."""
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
    from urllib.parse import parse_qs, urlparse

    if isinstance(frontend, ServingEngine):
        frontend.start(linger_ms=1.0)

    class Handler(BaseHTTPRequestHandler):
        def log_message(self, fmt, *args):  # quiet access log
            pass

        def _reply(self, status: int, payload: dict) -> None:
            body = json.dumps(payload).encode()
            self.send_response(status)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def do_GET(self):
            url = urlparse(self.path)
            if url.path != "/recommend":
                return self._reply(404, {"error": "GET /recommend?user=..."})
            qs = parse_qs(url.query)
            try:
                user = int(qs["user"][0])
                topk = int(qs.get("topk", [topk_default])[0])
                scores, items = frontend.submit(
                    user, topk, timeout=timeout
                ).result(timeout=timeout)
            except (KeyError, ValueError, IndexError) as exc:
                return self._reply(400, {"error": str(exc)})
            except QueueFullError as exc:
                return self._reply(503, {"error": str(exc)})
            except (RequestTimeout, TimeoutError) as exc:
                return self._reply(504, {"error": str(exc)})
            self._reply(200, {
                "user": user,
                "items": [
                    {"item": int(i), "score": round(float(s), 4)}
                    for i, s in zip(items, scores)
                ],
            })

    server = ThreadingHTTPServer(("127.0.0.1", port), Handler)
    print(f"# serving http://127.0.0.1:{port}/recommend?user=0&topk="
          f"{topk_default} (Ctrl-C to stop)")
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.server_close()
        _shutdown(frontend)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--ckpt", required=True)
    parser.add_argument("--users", type=int, nargs="+", default=[0])
    parser.add_argument("--topk", type=int, default=10)
    parser.add_argument("--batched-requests", type=int, default=0,
                        help="simulate N random-user requests and report latency")
    parser.add_argument("--concurrent", type=int, default=0,
                        help="simulate N single-user requests through the "
                             "async queue")
    parser.add_argument("--clients", type=int, default=32,
                        help="submitter threads for --concurrent")
    parser.add_argument("--timeout", type=float, default=30.0,
                        help="per-request timeout (seconds) for async modes")
    parser.add_argument("--http", type=int, default=0, metavar="PORT",
                        help="serve GET /recommend over HTTP on PORT")
    parser.add_argument("--max-batch", type=int, default=256,
                        help="micro-batch bucket cap")
    parser.add_argument("--use-kernel", action="store_true",
                        help="force the Pallas kernel path (default: TPU only)")
    parser.add_argument("--history", default=None,
                        help="(.npy) padded per-user item-history matrix for "
                             "SVD++ checkpoints (see data.build_user_history)")
    parser.add_argument("--replicas", type=int, default=1,
                        help="serve through a fleet of N replica engines "
                             "behind the cache-aware router (1 = single "
                             "engine, the classic path)")
    parser.add_argument("--replica-backend", choices=("local", "process"),
                        default="local",
                        help="fleet replicas in-process or as spawned "
                             "multiprocessing children")
    parser.add_argument("--routing", choices=("affinity", "least", "random"),
                        default="affinity",
                        help="fleet routing policy (see serving/fleet/router)")
    args = parser.parse_args()

    params, t_p, t_q, _, meta = load_mf_checkpoint(args.ckpt)
    user_history = None if args.history is None else np.load(args.history)
    if params.implicit is not None and user_history is None:
        print("# warning: SVD++ checkpoint served without --history — "
              "implicit factors contribute nothing (user vectors fall back "
              "to p alone)")
    engine_kwargs = dict(
        max_batch=args.max_batch,
        use_kernel=True if args.use_kernel else None,
        allow_missing_history=True,
    )
    engine = ServingEngine(
        params, t_p, t_q, user_history=user_history, **engine_kwargs
    )
    variant = (
        "svdpp" if params.implicit is not None
        else "bias" if params.user_bias is not None
        else "funk"
    )
    print(f"# loaded step {meta.get('step')} variant={variant} "
          f"({engine.num_users} users x {engine.n_items} items, k={engine.k})")

    frontend = engine
    if args.replicas > 1:
        from repro.serving.fleet import ServingFleet

        frontend = ServingFleet(
            params, t_p, t_q,
            replicas=args.replicas,
            backend=args.replica_backend,
            user_history=user_history,
            engine_kwargs=engine_kwargs,
            queue_kwargs={"linger_ms": 1.0},
            router_kwargs={"policy": args.routing},
        )
        print(f"# fleet: {args.replicas} {args.replica_backend} replicas, "
              f"routing={args.routing}")

    if args.http:
        return run_http(frontend, args.http, args.topk, args.timeout)

    recs = engine.recommend(args.users, topk=args.topk)
    print(json.dumps({str(u): r for u, r in zip(args.users, recs)}, indent=2))

    if args.batched_requests:
        rng = np.random.default_rng(0)
        users = rng.integers(0, engine.num_users, args.batched_requests)
        # warm every bucket the request mix hits (incl. the tail chunk's), so
        # no compile lands inside the timed region
        engine.topk(users, args.topk)
        start = time.perf_counter()
        engine.topk(users, args.topk)
        dt = time.perf_counter() - start
        print(f"batched: {args.batched_requests} requests in {dt:.3f}s "
              f"({args.batched_requests / dt:.1f} req/s)")

    if args.concurrent:
        run_concurrent(frontend, args.concurrent, args.clients, args.topk,
                       args.timeout)
    elif frontend is not engine:
        frontend.close()


if __name__ == "__main__":
    main()
