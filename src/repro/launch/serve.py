"""Serving launcher: batched top-k recommendation from a trained DP-MF
checkpoint, through the dynamically-pruned scoring path.

    PYTHONPATH=src python -m repro.launch.serve --ckpt /tmp/dpmf_ckpt \
        --users 0 1 2 --topk 10

Serving is the paper's "prediction" stage: one pruned (B, k) x (n, k) product
over the item catalog (the Pallas kernel on TPU; interpret mode here).
"""
from __future__ import annotations

import argparse
import json
import time

import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt_lib
from repro.core import mf


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--ckpt", required=True)
    parser.add_argument("--users", type=int, nargs="+", default=[0])
    parser.add_argument("--topk", type=int, default=10)
    parser.add_argument("--batched-requests", type=int, default=0,
                        help="simulate N random-user requests and report latency")
    parser.add_argument("--no-kernel", action="store_true")
    args = parser.parse_args()

    step = ckpt_lib.latest_step(args.ckpt)
    if step is None:
        raise SystemExit(f"no checkpoint under {args.ckpt}")
    with np.load(f"{args.ckpt}/step_{step:012d}/arrays.npz") as data:
        p = jnp.asarray(data["params__p"])
        q = jnp.asarray(data["params__q"])
        t_p = jnp.asarray(data["t_p"])
        t_q = jnp.asarray(data["t_q"])
    params = mf.MFParams(p=p, q=q, user_bias=None, item_bias=None,
                         global_mean=None, implicit=None)

    def recommend(user_ids):
        scores = mf.predict_all_items(
            params, jnp.asarray(user_ids, jnp.int32), t_p, t_q,
            use_kernel=not args.no_kernel,
        )
        top = np.asarray(jnp.argsort(-scores, axis=1)[:, : args.topk])
        return top, np.asarray(scores)

    top, scores = recommend(np.asarray(args.users))
    out = {
        str(u): [
            {"item": int(i), "score": round(float(scores[row, i]), 4)}
            for i in top[row]
        ]
        for row, u in enumerate(args.users)
    }
    print(json.dumps(out, indent=2))

    if args.batched_requests:
        rng = np.random.default_rng(0)
        users = rng.integers(0, p.shape[0], args.batched_requests)
        start = time.perf_counter()
        recommend(users)
        dt = time.perf_counter() - start
        print(f"batched: {args.batched_requests} requests in {dt:.3f}s "
              f"({args.batched_requests / dt:.1f} req/s)")


if __name__ == "__main__":
    main()
