"""Serving launcher: batched top-k recommendation from a trained DP-MF
checkpoint through the serving engine (``repro.serving``).

    PYTHONPATH=src python -m repro.launch.serve --ckpt /tmp/dpmf_ckpt \
        --users 0 1 2 --topk 10

The engine restores the FULL ``MFParams`` (biases and SVD++ implicit factors
included — not just ``p``/``q``), precomputes the per-item ranks and tiled
factor layout once at load, and answers requests through the streaming
pruned top-k path (Pallas kernel on TPU, ``lax.top_k``-merge scan on CPU)
without ever materializing the (B, n) score matrix.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.serving import ServingEngine, load_mf_checkpoint


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--ckpt", required=True)
    parser.add_argument("--users", type=int, nargs="+", default=[0])
    parser.add_argument("--topk", type=int, default=10)
    parser.add_argument("--batched-requests", type=int, default=0,
                        help="simulate N random-user requests and report latency")
    parser.add_argument("--max-batch", type=int, default=256,
                        help="micro-batch bucket cap")
    parser.add_argument("--use-kernel", action="store_true",
                        help="force the Pallas kernel path (default: TPU only)")
    parser.add_argument("--history", default=None,
                        help="(.npy) padded per-user item-history matrix for "
                             "SVD++ checkpoints (see data.build_user_history)")
    args = parser.parse_args()

    params, t_p, t_q, _, meta = load_mf_checkpoint(args.ckpt)
    user_history = None if args.history is None else np.load(args.history)
    if params.implicit is not None and user_history is None:
        print("# warning: SVD++ checkpoint served without --history — "
              "implicit factors contribute nothing (user vectors fall back "
              "to p alone)")
    engine = ServingEngine(
        params, t_p, t_q,
        max_batch=args.max_batch,
        use_kernel=True if args.use_kernel else None,
        user_history=user_history,
        allow_missing_history=True,
    )
    variant = (
        "svdpp" if params.implicit is not None
        else "bias" if params.user_bias is not None
        else "funk"
    )
    print(f"# loaded step {meta.get('step')} variant={variant} "
          f"({engine.num_users} users x {engine.n_items} items, k={engine.k})")

    recs = engine.recommend(args.users, topk=args.topk)
    print(json.dumps({str(u): r for u, r in zip(args.users, recs)}, indent=2))

    if args.batched_requests:
        rng = np.random.default_rng(0)
        users = rng.integers(0, engine.num_users, args.batched_requests)
        # warm every bucket the request mix hits (incl. the tail chunk's), so
        # no compile lands inside the timed region
        engine.topk(users, args.topk)
        start = time.perf_counter()
        engine.topk(users, args.topk)
        dt = time.perf_counter() - start
        print(f"batched: {args.batched_requests} requests in {dt:.3f}s "
              f"({args.batched_requests / dt:.1f} req/s)")


if __name__ == "__main__":
    main()
