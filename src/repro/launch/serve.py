"""Serving launcher: batched top-k recommendation from a trained DP-MF
checkpoint through the serving engine (``repro.serving``).

    PYTHONPATH=src python -m repro.launch.serve --ckpt /tmp/dpmf_ckpt \
        --users 0 1 2 --topk 10

The engine restores the FULL ``MFParams`` (biases and SVD++ implicit factors
included — not just ``p``/``q``), precomputes the per-item ranks and tiled
factor layout once at load, and answers requests through the streaming
pruned top-k path (Pallas kernel on TPU, ``lax.top_k``-merge scan on CPU)
without ever materializing the (B, n) score matrix.

Traffic modes on top of the one-shot lookup:

* ``--batched-requests N`` — one synchronous N-user batch (PR-1 behaviour);
* ``--concurrent N --clients C`` — N single-user requests from C client
  threads through the async request queue (``serving/queue.py``): continuous
  batching, deadline scheduling, per-request timeout; reports latency
  percentiles and throughput;
* ``--http PORT`` — a minimal event-loop server: every connection submits to
  the queue and blocks on its future, so concurrent HTTP clients coalesce
  into shared scoring launches.  ``GET /recommend?user=3&topk=10``.

``--slo-p99-ms BUDGET`` arms the SLO-aware degradation loop for
``--concurrent`` runs: an :class:`~repro.serving.slo.SLOController`
observes client latency and queue depth while the load runs and adapts the
pruning thresholds (up to ``--slo-max-rate``) to hold p99 under the
budget; the process exits non-zero if the steady-state p99 (back half of
the run) still violates it.

With ``--replicas N`` (N > 1) the same traffic modes run against a serving
*fleet* instead of a single engine: N replica engines
(``--replica-backend local`` in-process, ``process`` as spawned children)
behind the cache-aware router (``repro.serving.fleet``), so ``--http``
becomes the router's HTTP frontend and ``--concurrent`` measures routed
throughput.  ``--routing`` selects the policy (affinity/least/random).
"""
from __future__ import annotations

import argparse
import json
import threading
import time

import numpy as np

from repro.serving import (
    LatencyWindow,
    QueueFullError,
    RequestTimeout,
    ServingEngine,
    SLOConfig,
    SLOController,
    load_mf_checkpoint,
)


def build_slo_controller(frontend, params, *, p99_budget_ms: float,
                         max_rate: float, tick_ms: float) -> SLOController:
    """Attach an :class:`SLOController` to either frontend kind.

    Latency is observed client-side (one shared :class:`LatencyWindow` the
    traffic loop records into), which works uniformly for a single engine
    and for process-replica fleets where the queue lives in a child."""
    config = SLOConfig(
        p99_budget_ms=p99_budget_ms,
        max_rate=max_rate,
        tick_interval_s=tick_ms / 1e3,
    )
    window = LatencyWindow()
    if isinstance(frontend, ServingEngine):
        return SLOController(
            frontend, config=config, window=window,
            depth_fn=lambda: frontend.queue_depth,
        )
    return SLOController(
        config=config, window=window, router=frontend.router,
        depth_fn=lambda: sum(r.depth() for r in frontend.router.replicas),
        params_fn=lambda: params,
    )


def _shutdown(frontend) -> None:
    """Graceful drain for either frontend kind: ``ServingEngine.stop`` or
    ``ServingFleet.close`` — both complete in-flight requests first."""
    if isinstance(frontend, ServingEngine):
        frontend.stop()
    else:
        frontend.close()


def run_concurrent(frontend, n_requests: int, clients: int,
                   topk: int, timeout: float,
                   controller: SLOController | None = None) -> dict:
    """Drive the async frontend (one engine, or a routed fleet) from
    ``clients`` submitter threads.  With a ``controller`` the loop records
    client-observed latency into its window and ticks it continuously, so
    the pruning thresholds adapt while the load runs; the returned report
    includes the controller state and the steady-state p99 (second half of
    the run, after the control loop has had time to converge)."""
    from concurrent.futures import ThreadPoolExecutor

    queue = None
    rng = np.random.default_rng(0)
    users = rng.integers(0, frontend.num_users, n_requests)
    if isinstance(frontend, ServingEngine):
        queue = frontend.start(linger_ms=1.0,
                               max_pending=max(1024, n_requests))
        # warm every power-of-two bucket a batch can land in
        for b in (1, 2, 4, 8, 16, 32, 64):
            if b <= min(frontend.max_batch, n_requests):
                frontend.topk(users[:b], topk)

    latencies = np.empty(n_requests)
    done = [0]  # completion order, distinct from submission index i
    done_lock = threading.Lock()
    order = np.empty(n_requests)

    def client(i_u):
        i, u = i_u
        t0 = time.perf_counter()
        frontend.submit(int(u), topk, timeout=timeout).result(timeout=timeout)
        dt = time.perf_counter() - t0
        latencies[i] = dt
        if controller is not None:
            controller.window.record(dt)
        with done_lock:
            order[done[0]] = dt
            done[0] += 1

    stop_tick = threading.Event()

    def ticker():
        while not stop_tick.is_set():
            controller.maybe_tick()
            stop_tick.wait(controller.config.tick_interval_s / 4)

    tick_thread = None
    if controller is not None:
        tick_thread = threading.Thread(target=ticker, daemon=True)
        tick_thread.start()

    start = time.perf_counter()
    with ThreadPoolExecutor(max_workers=clients) as pool:
        list(pool.map(client, enumerate(users)))
    wall = time.perf_counter() - start
    if tick_thread is not None:
        stop_tick.set()
        tick_thread.join(10)
    stats = None if queue is not None else frontend.stats()
    _shutdown(frontend)
    p50, p99 = np.percentile(latencies * 1e3, [50, 99])
    line = (f"concurrent: {n_requests} requests, {clients} clients in "
            f"{wall:.3f}s ({n_requests / wall:.1f} req/s; p50 {p50:.2f} ms, "
            f"p99 {p99:.2f} ms")
    if queue is not None:
        line += (f"; {queue.batches_served} launches, mean batch "
                 f"{queue.requests_served / queue.batches_served:.1f})")
    else:
        line += (f"; routed over {len(stats['replicas'])} replicas, "
                 f"policy={stats['policy']}, "
                 f"affinity hits {stats['affinity_hits']})")
    print(line)

    report = {
        "requests": n_requests,
        "wall_s": wall,
        "req_per_s": n_requests / wall,
        "p50_ms": float(p50),
        "p99_ms": float(p99),
    }
    if controller is not None:
        # judge the SLO on the back half of completions: the front half is
        # the controller still hunting for an operating point
        steady = order[n_requests // 2:done[0]]
        steady_p99 = (
            float(np.percentile(steady * 1e3, 99)) if steady.size
            else float("nan")
        )
        report["slo"] = controller.report()
        report["steady_p99_ms"] = steady_p99
        report["slo_violated"] = bool(
            np.isfinite(steady_p99)
            and steady_p99 > controller.config.p99_budget_ms
        )
        print(f"slo: steady-state p99 {steady_p99:.2f} ms vs budget "
              f"{controller.config.p99_budget_ms:.2f} ms "
              f"({'VIOLATED' if report['slo_violated'] else 'ok'}); "
              f"rate {report['slo']['applied_rate']}, "
              f"{report['slo']['degrades']} degrades / "
              f"{report['slo']['relaxes']} relaxes over "
              f"{report['slo']['ticks']} ticks")
    return report


def run_http(frontend, port: int, topk_default: int,
             timeout: float) -> None:
    """Blocking HTTP front end over the async queue — or, for a fleet, over
    the router (stdlib only).  Shutdown drains: in-flight requests complete
    before the process exits."""
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
    from urllib.parse import parse_qs, urlparse

    if isinstance(frontend, ServingEngine):
        frontend.start(linger_ms=1.0)

    class Handler(BaseHTTPRequestHandler):
        def log_message(self, fmt, *args):  # quiet access log
            pass

        def _reply(self, status: int, payload: dict) -> None:
            body = json.dumps(payload).encode()
            self.send_response(status)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def do_GET(self):
            url = urlparse(self.path)
            if url.path != "/recommend":
                return self._reply(404, {"error": "GET /recommend?user=..."})
            qs = parse_qs(url.query)
            try:
                user = int(qs["user"][0])
                topk = int(qs.get("topk", [topk_default])[0])
                scores, items = frontend.submit(
                    user, topk, timeout=timeout
                ).result(timeout=timeout)
            except (KeyError, ValueError, IndexError) as exc:
                return self._reply(400, {"error": str(exc)})
            except QueueFullError as exc:
                return self._reply(503, {"error": str(exc)})
            except (RequestTimeout, TimeoutError) as exc:
                return self._reply(504, {"error": str(exc)})
            self._reply(200, {
                "user": user,
                "items": [
                    {"item": int(i), "score": round(float(s), 4)}
                    for i, s in zip(items, scores)
                ],
            })

    server = ThreadingHTTPServer(("127.0.0.1", port), Handler)
    print(f"# serving http://127.0.0.1:{port}/recommend?user=0&topk="
          f"{topk_default} (Ctrl-C to stop)")
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.server_close()
        _shutdown(frontend)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--ckpt", required=True)
    parser.add_argument("--users", type=int, nargs="+", default=[0])
    parser.add_argument("--topk", type=int, default=10)
    parser.add_argument("--batched-requests", type=int, default=0,
                        help="simulate N random-user requests and report latency")
    parser.add_argument("--concurrent", type=int, default=0,
                        help="simulate N single-user requests through the "
                             "async queue")
    parser.add_argument("--clients", type=int, default=32,
                        help="submitter threads for --concurrent")
    parser.add_argument("--timeout", type=float, default=30.0,
                        help="per-request timeout (seconds) for async modes")
    parser.add_argument("--http", type=int, default=0, metavar="PORT",
                        help="serve GET /recommend over HTTP on PORT")
    parser.add_argument("--max-batch", type=int, default=256,
                        help="micro-batch bucket cap")
    parser.add_argument("--use-kernel", action="store_true",
                        help="force the Pallas kernel path (default: TPU only)")
    parser.add_argument("--history", default=None,
                        help="(.npy) padded per-user item-history matrix for "
                             "SVD++ checkpoints (see data.build_user_history)")
    parser.add_argument("--replicas", type=int, default=1,
                        help="serve through a fleet of N replica engines "
                             "behind the cache-aware router (1 = single "
                             "engine, the classic path)")
    parser.add_argument("--replica-backend", choices=("local", "process"),
                        default="local",
                        help="fleet replicas in-process or as spawned "
                             "multiprocessing children")
    parser.add_argument("--routing", choices=("affinity", "least", "random"),
                        default="affinity",
                        help="fleet routing policy (see serving/fleet/router)")
    parser.add_argument("--slo-p99-ms", type=float, default=0.0,
                        help="enable the SLO controller with this p99 "
                             "latency budget (ms) for --concurrent; the "
                             "process exits non-zero if the steady-state "
                             "p99 still violates the budget (0 = off)")
    parser.add_argument("--slo-max-rate", type=float, default=0.8,
                        help="ceiling on the controller's effective pruning "
                             "rate (the quality floor)")
    parser.add_argument("--slo-tick-ms", type=float, default=100.0,
                        help="controller tick interval (ms)")
    args = parser.parse_args()

    params, t_p, t_q, _, meta = load_mf_checkpoint(args.ckpt)
    user_history = None if args.history is None else np.load(args.history)
    if params.implicit is not None and user_history is None:
        print("# warning: SVD++ checkpoint served without --history — "
              "implicit factors contribute nothing (user vectors fall back "
              "to p alone)")
    engine_kwargs = dict(
        max_batch=args.max_batch,
        use_kernel=True if args.use_kernel else None,
        allow_missing_history=True,
    )
    engine = ServingEngine(
        params, t_p, t_q, user_history=user_history, **engine_kwargs
    )
    variant = (
        "svdpp" if params.implicit is not None
        else "bias" if params.user_bias is not None
        else "funk"
    )
    print(f"# loaded step {meta.get('step')} variant={variant} "
          f"({engine.num_users} users x {engine.n_items} items, k={engine.k})")

    frontend = engine
    if args.replicas > 1:
        from repro.serving.fleet import ServingFleet

        frontend = ServingFleet(
            params, t_p, t_q,
            replicas=args.replicas,
            backend=args.replica_backend,
            user_history=user_history,
            engine_kwargs=engine_kwargs,
            queue_kwargs={"linger_ms": 1.0},
            router_kwargs={"policy": args.routing},
        )
        print(f"# fleet: {args.replicas} {args.replica_backend} replicas, "
              f"routing={args.routing}")

    if args.http:
        return run_http(frontend, args.http, args.topk, args.timeout)

    recs = engine.recommend(args.users, topk=args.topk)
    print(json.dumps({str(u): r for u, r in zip(args.users, recs)}, indent=2))

    if args.batched_requests:
        rng = np.random.default_rng(0)
        users = rng.integers(0, engine.num_users, args.batched_requests)
        # warm every bucket the request mix hits (incl. the tail chunk's), so
        # no compile lands inside the timed region
        engine.topk(users, args.topk)
        start = time.perf_counter()
        engine.topk(users, args.topk)
        dt = time.perf_counter() - start
        print(f"batched: {args.batched_requests} requests in {dt:.3f}s "
              f"({args.batched_requests / dt:.1f} req/s)")

    if args.concurrent:
        controller = None
        if args.slo_p99_ms > 0:
            controller = build_slo_controller(
                frontend, params,
                p99_budget_ms=args.slo_p99_ms,
                max_rate=args.slo_max_rate,
                tick_ms=args.slo_tick_ms,
            )
            print(f"# slo: p99 budget {args.slo_p99_ms} ms, floor rate "
                  f"{controller.floor_rate:.3f}, max rate "
                  f"{args.slo_max_rate}")
        report = run_concurrent(frontend, args.concurrent, args.clients,
                                args.topk, args.timeout,
                                controller=controller)
        if report.get("slo_violated"):
            raise SystemExit(
                f"SLO violated: steady-state p99 "
                f"{report['steady_p99_ms']:.2f} ms > budget "
                f"{args.slo_p99_ms:.2f} ms"
            )
    elif frontend is not engine:
        frontend.close()


if __name__ == "__main__":
    main()
