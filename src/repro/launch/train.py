"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --dataset movielens100k \
        --pruning-rate 0.3 --epochs 15 --k 50 --ckpt /tmp/dpmf_ckpt

Runs the paper's full DP-MF pipeline (epoch-1 dense -> threshold ->
rearrange -> pruned epochs) with fault-tolerant stepping: bounded retries
around each epoch, straggler timing detection, and async checkpointing.
Restarting the same command resumes from the latest checkpoint (identical
data order — see data/loader.py).
"""
from __future__ import annotations

import argparse
import json

from repro.core.trainer import DPMFTrainer, TrainConfig, work_speedup
from repro.data.ratings import paper_dataset, train_test_split
from repro.distributed.fault_tolerance import (
    StragglerDetector,
    run_with_retries,
)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--dataset", default="movielens100k",
                        choices=["movielens100k", "appliances",
                                 "bookcrossings", "jester"])
    parser.add_argument("--scale", type=float, default=1.0,
                        help="dataset size multiplier")
    parser.add_argument("--k", type=int, default=50)
    parser.add_argument("--epochs", type=int, default=15)
    parser.add_argument("--batch-size", type=int, default=4096)
    parser.add_argument("--lr", type=float, default=0.05)
    parser.add_argument("--lam", type=float, default=0.02)
    parser.add_argument("--pruning-rate", type=float, default=0.3)
    parser.add_argument("--optimizer", default="adagrad",
                        choices=["sgd", "momentum", "adagrad", "adadelta",
                                 "adam"])
    parser.add_argument("--epoch-mode", default="scan",
                        choices=["scan", "python"],
                        help="scan: whole epoch as one donated lax.scan "
                             "(device-resident data); python: legacy "
                             "per-batch host loop")
    parser.add_argument("--strategy", default="standard",
                        choices=["standard", "twin"])
    parser.add_argument("--init", default="normal", choices=["normal", "uniform"])
    parser.add_argument("--variant", default="funk",
                        choices=["funk", "bias", "svdpp"])
    parser.add_argument("--objective", default="explicit",
                        choices=["explicit", "implicit", "bpr"],
                        help="explicit: squared rating error (the paper); "
                             "implicit: WALS confidence-weighted binary "
                             "preference with sampled negatives; bpr: "
                             "pairwise ranking loss (test mae is NaN)")
    parser.add_argument("--implicit-alpha", type=float, default=40.0,
                        help="implicit confidence c = 1 + alpha*r")
    parser.add_argument("--implicit-negatives", type=int, default=4,
                        help="sampled negatives per observed interaction")
    parser.add_argument("--use-fused-kernel", action="store_true")
    parser.add_argument("--seed", type=int, default=0)
    parser.add_argument("--ckpt", default=None)
    parser.add_argument("--ckpt-every", type=int, default=5)
    parser.add_argument("--store-dir", default=None,
                        help="train out-of-core from this ratings store "
                             "directory (mmap + streamed slabs) instead of "
                             "loading the dataset into memory")
    parser.add_argument("--build-store", action="store_true",
                        help="with --store-dir: build the store from the "
                             "selected dataset's train split first, then "
                             "train from it")
    parser.add_argument("--slab-steps", type=int, default=256,
                        help="steps per streamed slab (store mode)")
    parser.add_argument("--prefetch-slabs", type=int, default=2,
                        help="bounded prefetch queue depth (store mode)")
    parser.add_argument("--ckpt-every-slabs", type=int, default=0,
                        help="mid-epoch checkpoint every N slabs (store "
                             "mode; 0 = epoch boundaries only)")
    args = parser.parse_args()

    train_ds = test_ds = None
    if args.store_dir is None or args.build_store:
        ds = paper_dataset(args.dataset, seed=args.seed, scale=args.scale)
        train_ds, test_ds = train_test_split(ds, 0.2, seed=args.seed)
    if args.store_dir is not None:
        if args.build_store:
            from repro.store import build_store

            build_store(train_ds, args.store_dir)
            print(f"built store: {len(train_ds)} ratings at {args.store_dir}")
        # the dataset object is no longer needed — the point of the store
        # is that the ratings never have to fit in host memory
        train_ds = None

    config = TrainConfig(
        k=args.k,
        epochs=args.epochs,
        batch_size=args.batch_size,
        lr=args.lr,
        lam=args.lam,
        pruning_rate=args.pruning_rate,
        optimizer=args.optimizer,
        strategy=args.strategy,
        init_method=args.init,
        variant=args.variant,
        objective=args.objective,
        implicit_alpha=args.implicit_alpha,
        implicit_negatives=args.implicit_negatives,
        use_fused_kernel=args.use_fused_kernel,
        epoch_mode=args.epoch_mode,
        seed=args.seed,
        checkpoint_dir=args.ckpt,
        checkpoint_every_epochs=args.ckpt_every,
        store_dir=args.store_dir,
        slab_steps=args.slab_steps,
        prefetch_slabs=args.prefetch_slabs,
        checkpoint_every_slabs=args.ckpt_every_slabs,
    )
    trainer = DPMFTrainer(config, train_ds, test_ds)
    if trainer.maybe_restore():
        print(f"resumed from checkpoint at epoch {trainer.epoch}")

    detector = StragglerDetector(window=20, z_threshold=4.0)
    while trainer.epoch < config.epochs:
        record = run_with_retries(trainer.run_epoch, max_retries=3)
        straggler = detector.record(record.wall_time_s)
        print(
            f"epoch {record.epoch:3d}  mae={record.test_mae:.4f}  "
            f"work={record.work_fraction:.3f}  t={record.wall_time_s:.2f}s"
            + ("  [straggler-flagged]" if straggler else "")
        )
    if trainer._ckpt is not None:
        trainer.save(trainer._ckpt_step())
        trainer._ckpt.wait()

    print(json.dumps({
        "final_mae": trainer.history[-1].test_mae,
        "work_speedup": work_speedup(trainer.history),
        "total_time_s": trainer.total_train_time(),
        "t_p": trainer.history[-1].t_p,
        "t_q": trainer.history[-1].t_q,
    }, indent=2))


if __name__ == "__main__":
    main()
