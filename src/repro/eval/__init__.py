"""Evaluation subsystem: the fourth pillar (train → serve → refresh →
**evaluate**).

Two complementary views of the paper's speed/accuracy trade, measured
continuously instead of against a stale held-out split:

* :mod:`repro.eval.prequential` — test-then-learn error on the live event
  stream (windowed / decayed MAE & RMSE, drift hooks for recalibration);
* :mod:`repro.eval.ranking` — HR@K / NDCG@K / recall@K through the real
  serving paths, pinned against a brute-force dense oracle, so pruning
  error is visible as *ranking* degradation, not only rating error;
* :mod:`repro.eval.prequential_ranking` — the rating-free variant: "was
  the clicked item in the top-k we actually served?", test-then-learn on
  click streams with new/established user cohort segmentation.
"""
from repro.eval.prequential import (
    PrequentialEvaluator,
    PrequentialStats,
    recalibration_hook,
)
from repro.eval.prequential_ranking import (
    PrequentialRankingEvaluator,
    PrequentialRankingStats,
)
from repro.eval.ranking import (
    PAD_ITEM,
    RankingReport,
    dense_topk,
    evaluate_engine,
    evaluate_oracle,
    ndcg_discounts,
    pack_ranking_batches,
    ranking_counts,
    relevance_from_dataset,
)

__all__ = [
    "PAD_ITEM",
    "PrequentialEvaluator",
    "PrequentialRankingEvaluator",
    "PrequentialRankingStats",
    "PrequentialStats",
    "RankingReport",
    "dense_topk",
    "evaluate_engine",
    "evaluate_oracle",
    "ndcg_discounts",
    "pack_ranking_batches",
    "ranking_counts",
    "recalibration_hook",
    "relevance_from_dataset",
]
