"""Prequential (test-then-learn) evaluation folded into the online path.

The offline trainer measures accuracy against a static held-out split — a
split that goes stale the moment the online updater starts moving the
factors.  Prequential evaluation is the streaming fix: every incoming
event batch is **first predicted** with the current model (through the same
pruned forward pass serving uses) and scored, **then applied** as a
training update.  Each event is scored exactly once, by a model that has
never seen it, so the running error is an honest, continuously-fresh
estimate of online accuracy — no second holdout needed, and no event is
wasted on eval only.

:class:`PrequentialEvaluator` wraps an
:class:`~repro.online.updater.OnlineUpdater` and maintains three error
views over the stream, each answering a different question:

* **cumulative** MAE/RMSE — lifetime average; the number to compare against
  an offline recompute (they match to float tolerance by construction);
* **windowed** MAE/RMSE over the last ``window`` events — "how is the model
  doing *right now*"; this is what drift detection keys off;
* **exponentially-decayed** MAE/RMSE with an ``half_life_events`` half-life
  — a smooth long-term baseline between the two.

Drift hooks close the loop the ROADMAP asked for: after every consumed
batch each hook sees the current :class:`PrequentialStats`, so threshold
recalibration can key off *prequential error* (the model is getting worse
at predicting the live stream) instead of a stale test set —
:func:`recalibration_hook` packages that policy.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mf
from repro.online.stream import EventBatch, RatingFreeStreamError


@dataclasses.dataclass(frozen=True)
class PrequentialStats:
    """One consistent view of the evaluator's error accumulators."""

    events: int          # events scored so far
    mae: float           # cumulative prequential MAE
    rmse: float          # cumulative prequential RMSE
    window_mae: float    # over the last `window` events
    window_rmse: float
    window_events: int   # events currently in the window (<= window)
    ema_mae: float       # exponentially-decayed, bias-corrected
    ema_rmse: float

    def as_dict(self) -> Dict[str, float]:
        """Flat summary for JSON run reports."""
        return dataclasses.asdict(self)


@jax.jit
def _prequential_errors(params, user, item, rating, t_p, t_q, hist=None):
    """Per-event |err| and err^2 of the *pre-update* model — the pruned
    forward pass (``mf.predict_pairs``) serving scores with."""
    pred, _ = mf.predict_pairs(params, user, item, t_p, t_q, hist)
    err = rating.astype(jnp.float32) - pred
    return jnp.abs(err), err * err


class _EventWindow:
    """Fixed-capacity ring buffer of per-event (|err|, err^2) pairs.

    Exact event-granular windowing (not batch-granular): a batch larger
    than the window keeps only its newest ``capacity`` events, a trickle of
    small batches ages out one event at a time.
    """

    def __init__(self, capacity: int):
        if capacity <= 0:
            raise ValueError(f"window must be positive, got {capacity}")
        self.capacity = capacity
        self._abs = np.zeros(capacity, np.float64)
        self._sq = np.zeros(capacity, np.float64)
        self._pos = 0
        self.count = 0

    def extend(self, abs_err: np.ndarray, sq_err: np.ndarray) -> None:
        n = abs_err.size
        if n >= self.capacity:  # batch alone overflows: keep the newest
            self._abs[:] = abs_err[n - self.capacity:]
            self._sq[:] = sq_err[n - self.capacity:]
            self._pos, self.count = 0, self.capacity
            return
        idx = (self._pos + np.arange(n)) % self.capacity
        self._abs[idx] = abs_err
        self._sq[idx] = sq_err
        self._pos = int((self._pos + n) % self.capacity)
        self.count = min(self.count + n, self.capacity)

    def means(self):
        if self.count == 0:
            return float("nan"), float("nan")
        denom = float(self.count)
        if self.count < self.capacity:
            abs_sum = float(self._abs[: self.count].sum())
            sq_sum = float(self._sq[: self.count].sum())
        else:
            abs_sum, sq_sum = float(self._abs.sum()), float(self._sq.sum())
        return abs_sum / denom, float(np.sqrt(sq_sum / denom))


class PrequentialEvaluator:
    """Test-then-learn wrapper around an ``OnlineUpdater``.

    ``consume(batch)`` is the one-call online loop body: score the batch
    with the pre-update model, fold the errors into the running stats,
    apply the batch as a pruned row update, then fire the drift hooks.
    ``score(batch)`` does only the first half (pure evaluation, no model
    movement) — e.g. for shadow-scoring a stream the updater does not own.

    Ordering guarantees (pinned by ``tests/test_eval_prequential.py``):

    * a rated event NEVER influences its own prediction — scoring happens
      strictly before ``updater.apply``, including the SVD++ history append
      (the event enters its user's implicit set only after being scored);
    * cold-start ids are scored against freshly initialized rows (the
      tables grow *before* prediction — growth draws from the init
      distribution, not from the event's rating, so the prediction is still
      untainted) — the honest prequential cost of an unknown user/item.

    Event ``weight`` columns (recency importance weighting) gate *updates*,
    not evaluation: prequential stats count every event equally.
    """

    def __init__(
        self,
        updater,
        *,
        window: int = 2048,
        half_life_events: float = 4096.0,
        drift_hooks: Optional[
            List[Callable[[PrequentialStats], None]]
        ] = None,
    ):
        if half_life_events <= 0:
            raise ValueError(
                f"half_life_events must be positive, got {half_life_events}"
            )
        self.updater = updater
        self.window = _EventWindow(window)
        self._decay = 0.5 ** (1.0 / float(half_life_events))
        self._hooks = list(drift_hooks or [])
        self.events = 0
        self._abs_sum = 0.0       # float64 lifetime accumulators
        self._sq_sum = 0.0
        self._ema_abs = 0.0       # decayed sums + their weight normalizer
        self._ema_sq = 0.0
        self._ema_norm = 0.0

    def add_drift_hook(
        self, hook: Callable[[PrequentialStats], None]
    ) -> None:
        """Register ``hook(stats)``, called after every :meth:`consume`."""
        self._hooks.append(hook)

    # -- scoring -------------------------------------------------------------
    def score(self, batch: EventBatch) -> Dict[str, float]:
        """Score one batch against the CURRENT model (no update).

        Returns the batch's own ``{"mae", "rmse", "events"}``; the running
        views live on :attr:`stats`.  Ids past the current tables trigger
        cold-start growth first (see the class docstring).
        """
        if len(batch) == 0:
            return {"mae": float("nan"), "rmse": float("nan"), "events": 0}
        if batch.rating is None:
            raise RatingFreeStreamError(
                "PrequentialEvaluator scores rating error and needs a rated "
                "stream; this batch is rating-free.  Use "
                "repro.eval.prequential_ranking.PrequentialRankingEvaluator "
                "for ranking-only prequential evaluation of click streams."
            )
        users = np.asarray(batch.user, np.int32)
        items = np.asarray(batch.item, np.int32)
        # grow BEFORE predicting: a fresh row's prediction is rating-free.
        # resolve_users handles eviction remapping too — an evicted user is
        # revived from spill so the pre-update score sees its learned row.
        users = self.updater.resolve_users(users)
        self.updater.ensure_capacity(-1, int(items.max()))
        hist = (
            None if self.updater.user_history is None
            else jnp.asarray(self.updater.user_history[users])
        )
        abs_err, sq_err = _prequential_errors(
            self.updater.params,
            jnp.asarray(users),
            jnp.asarray(items),
            jnp.asarray(np.asarray(batch.rating, np.float32)),
            self.updater.t_p,
            self.updater.t_q,
            hist,
        )
        abs_err = np.asarray(abs_err, np.float64)
        sq_err = np.asarray(sq_err, np.float64)
        self._fold(abs_err, sq_err)
        n = abs_err.size
        return {
            "mae": float(abs_err.sum() / n),
            "rmse": float(np.sqrt(sq_err.sum() / n)),
            "events": n,
        }

    def consume(self, batch: EventBatch) -> Dict[str, float]:
        """Test-then-learn: :meth:`score`, then ``updater.apply``, then the
        drift hooks.  Returns the batch's eval metrics merged with the
        updater's step metrics (``abs_err``/``work_fraction``)."""
        eval_metrics = self.score(batch)
        update_metrics = self.updater.apply(batch) if len(batch) else {}
        stats = self.stats
        for hook in self._hooks:
            hook(stats)
        return {**update_metrics, **eval_metrics}

    def _fold(self, abs_err: np.ndarray, sq_err: np.ndarray) -> None:
        n = abs_err.size
        self.events += n
        self._abs_sum += float(abs_err.sum())
        self._sq_sum += float(sq_err.sum())
        self.window.extend(abs_err, sq_err)
        # exact per-event EMA, vectorized over the batch: applying
        # m <- d*m + (1-d)*e for e_0..e_{n-1} in order collapses to one
        # weighted sum with weights (1-d) * d^(n-1-j)
        d = self._decay
        tail = (1.0 - d) * d ** np.arange(n - 1, -1, -1, dtype=np.float64)
        scale = d ** n
        self._ema_abs = self._ema_abs * scale + float(tail @ abs_err)
        self._ema_sq = self._ema_sq * scale + float(tail @ sq_err)
        self._ema_norm = self._ema_norm * scale + float(tail.sum())

    # -- views ---------------------------------------------------------------
    @property
    def stats(self) -> PrequentialStats:
        """Current error views (see the class docstring for which is which)."""
        n = max(self.events, 1)
        win_mae, win_rmse = self.window.means()
        norm = max(self._ema_norm, 1e-12)
        return PrequentialStats(
            events=self.events,
            mae=self._abs_sum / n,
            rmse=float(np.sqrt(self._sq_sum / n)),
            window_mae=win_mae,
            window_rmse=win_rmse,
            window_events=self.window.count,
            ema_mae=self._ema_abs / norm,
            ema_rmse=float(np.sqrt(self._ema_sq / norm)),
        )


def recalibration_hook(
    updater,
    *,
    degradation: float = 1.2,
    min_events: int = 1024,
    cooldown_events: int = 4096,
) -> Callable[[PrequentialStats], None]:
    """Drift hook: recalibrate thresholds when prequential error degrades.

    Fires ``updater.maybe_recalibrate(force=True)`` when the *windowed* MAE
    exceeds ``degradation`` × the decayed long-term baseline (``ema_mae``)
    — i.e. recalibration keys off the model visibly getting worse at
    predicting the live stream, not off a stale test set.  ``min_events``
    gates early noise; ``cooldown_events`` spaces consecutive firings.
    The returned hook records its firings on its ``fired`` list attribute.
    """
    state = {"last": -cooldown_events}
    fired: List[int] = []

    def hook(stats: PrequentialStats) -> None:
        if stats.events < min_events:
            return
        if stats.events - state["last"] < cooldown_events:
            return
        if not np.isfinite(stats.window_mae) or stats.ema_mae <= 0:
            return
        if stats.window_mae > degradation * stats.ema_mae:
            if updater.maybe_recalibrate(force=True) is not None:
                state["last"] = stats.events
                fired.append(stats.events)

    hook.fired = fired
    return hook
