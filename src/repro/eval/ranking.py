"""Ranking-quality metrics for pruned serving: HR@K / NDCG@K / recall@K.

The paper reports the cost of pruning as a rating-error increase (P_MAE,
Eq. 13).  A recommender, however, *serves rankings*: what reaches the user
is the engine's top-k, so the quantity that must stay inside the paper's
error band is ranking degradation — does the pruned top-k still surface the
items the user actually interacted with?  This module makes that measurable
on every path the engine serves from:

* :func:`ranking_counts` — the metric kernel: batched HR@K / NDCG@K /
  recall@K sums from ``(B, K)`` recommended ids against padded per-user
  relevance sets, pure ``jnp`` so it runs jitted on device (it is the body
  of ``mf.eval_ranking_epoch_scan`` and of the engine evaluators below);
* :func:`dense_topk` — the brute-force oracle: dense (or
  threshold-masked) scoring of the full catalog + stable argsort, the same
  reference the serving parity tests pin against.  At thresholds 0 every
  engine path returns *identical* indices, so engine metrics match oracle
  metrics exactly — any gap at trained thresholds is pruning, not plumbing;
* :func:`evaluate_engine` / :func:`evaluate_oracle` — end-to-end: build
  relevance sets from a held-out :class:`~repro.data.ratings.RatingsDataset`,
  rank through ``ServingEngine.topk`` (or ``topk_sharded`` on a mesh, or the
  Pallas kernel path — whatever the engine is configured with) or the
  oracle, and reduce to one :class:`RankingReport`.

Metric definitions (binary relevance, per evaluated user ``u`` with
held-out item set ``R_u``; users with empty ``R_u`` are excluded):

* ``HR@K``      — 1 if the top-K contains any item of ``R_u``;
* ``recall@K``  — ``|topK ∩ R_u| / |R_u|``;
* ``NDCG@K``    — ``DCG@K / IDCG@K`` with gain ``1 / log2(pos + 2)`` at
  0-based position ``pos``; ``IDCG@K`` places ``min(K, |R_u|)`` hits at the
  head, so a user whose whole holdout is retrieved in order scores 1.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mf

PAD_ITEM = -1  # relevance padding: never equals a valid item id


# ---------------------------------------------------------------------------
# Device-side metric kernel
# ---------------------------------------------------------------------------


def ndcg_discounts(k: int) -> jnp.ndarray:
    """``(K,)`` DCG position discounts ``1 / log2(pos + 2)``, 0-based."""
    pos = jnp.arange(k, dtype=jnp.float32)
    return 1.0 / jnp.log2(pos + 2.0)


def ranking_counts(
    topk_idx: jax.Array,    # (B, K) recommended item ids, best first
    relevant: jax.Array,    # (B, R) held-out item ids, PAD_ITEM-padded
    n_valid: jax.Array,     # (B,)   |R_u| per row
    weight: Optional[jax.Array] = None,  # (B,) 0 masks padding rows
) -> Dict[str, jax.Array]:
    """Summed HR@K / NDCG@K / recall@K over a batch — pure jnp, jit-safe.

    Returns ``{"hr_sum", "ndcg_sum", "recall_sum", "weight_sum"}`` scalars;
    divide the metric sums by ``weight_sum`` for per-user means.  Rows with
    ``n_valid == 0`` (or zero ``weight``) contribute nothing, so packed
    batches can pad with inert rows exactly like ``eval_epoch_scan``.
    """
    k = topk_idx.shape[-1]
    w = (
        jnp.ones(topk_idx.shape[:1], jnp.float32)
        if weight is None
        else weight.astype(jnp.float32)
    )
    w = w * (n_valid > 0).astype(jnp.float32)
    # (B, K) hit mask: is the j-th recommendation in the user's holdout?
    hits = jnp.any(
        topk_idx[:, :, None] == relevant[:, None, :], axis=-1
    ).astype(jnp.float32)
    disc = ndcg_discounts(k)
    dcg = jnp.sum(hits * disc[None, :], axis=-1)
    # ideal DCG: all min(K, |R_u|) hits packed at the head
    ideal = jnp.cumsum(disc)                       # (K,) prefix sums
    n_ideal = jnp.clip(n_valid, 1, k)              # clip(·,1,·): avoid 0 gather
    idcg = ideal[n_ideal - 1]
    hit_count = jnp.sum(hits, axis=-1)
    safe_valid = jnp.maximum(n_valid.astype(jnp.float32), 1.0)
    return {
        "hr_sum": jnp.sum(w * (hit_count > 0).astype(jnp.float32)),
        "ndcg_sum": jnp.sum(w * dcg / idcg),
        "recall_sum": jnp.sum(w * hit_count / safe_valid),
        "weight_sum": jnp.sum(w),
    }


_ranking_counts_jit = jax.jit(ranking_counts)


# ---------------------------------------------------------------------------
# Relevance sets from a held-out ratings split
# ---------------------------------------------------------------------------


def relevance_from_dataset(
    ds,
    *,
    min_rating: Optional[float] = None,
    max_users: Optional[int] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-user relevance sets from a held-out split.

    Returns ``(users, relevant, counts)``: the evaluated user ids ``(U,)``,
    their held-out items ``(U, R)`` padded with :data:`PAD_ITEM`, and the
    per-user set sizes ``(U,)``.  ``min_rating`` keeps only interactions at
    or above it (binary-relevance cut); users left with no relevant items
    are excluded.  ``max_users`` truncates to the first U evaluated users
    (ascending id) to bound eval cost; None (not 0) means no cap.
    """
    if max_users is not None and max_users <= 0:
        raise ValueError(
            f"max_users must be positive (or None for no cap), got {max_users}"
        )
    user = np.asarray(ds.user, np.int64)
    item = np.asarray(ds.item, np.int64)
    if min_rating is not None:
        keep = np.asarray(ds.rating, np.float32) >= min_rating
        user, item = user[keep], item[keep]
    if user.size == 0:
        return (
            np.zeros(0, np.int32),
            np.zeros((0, 1), np.int32),
            np.zeros(0, np.int32),
        )
    order = np.lexsort((item, user))
    user, item = user[order], item[order]
    # unique (user, item) pairs — duplicate interactions are one relevance
    first = np.ones(user.size, bool)
    first[1:] = (user[1:] != user[:-1]) | (item[1:] != item[:-1])
    user, item = user[first], item[first]
    uniq, counts = np.unique(user, return_counts=True)
    if max_users is not None:
        uniq, counts = uniq[:max_users], counts[:max_users]
        keep = user <= uniq[-1]
        user, item = user[keep], item[keep]
    width = int(counts.max())
    relevant = np.full((uniq.size, width), PAD_ITEM, np.int32)
    starts = np.zeros(uniq.size + 1, np.int64)
    np.cumsum(counts, out=starts[1:])
    for row, (lo, hi) in enumerate(zip(starts[:-1], starts[1:])):
        relevant[row, : hi - lo] = item[lo:hi]
    return uniq.astype(np.int32), relevant, counts.astype(np.int32)


def pack_ranking_batches(
    ds,
    batch_size: int,
    *,
    min_rating: Optional[float] = None,
    max_users: Optional[int] = None,
) -> Dict[str, jnp.ndarray]:
    """Pre-packed ``(steps, B, ...)`` operands for ``mf.eval_ranking_epoch_scan``.

    The ranking analogue of :func:`repro.data.loader.pack_eval_batches`:
    evaluated users and their padded relevance sets are uploaded once, with
    a zero ``weight`` column marking the padded tail, so the whole ranking
    eval runs as one compiled scan fetched in a single host sync.
    """
    users, relevant, counts = relevance_from_dataset(
        ds, min_rating=min_rating, max_users=max_users
    )
    n_users = users.size
    if n_users == 0:
        raise ValueError("no users with relevant held-out items to evaluate")
    batch_size = min(batch_size, n_users)
    steps = -(-n_users // batch_size)
    pad = steps * batch_size - n_users
    users = np.concatenate([users, np.zeros(pad, np.int32)])
    relevant = np.concatenate(
        [relevant, np.full((pad, relevant.shape[1]), PAD_ITEM, np.int32)]
    )
    counts = np.concatenate([counts, np.zeros(pad, np.int32)])
    weight = np.concatenate(
        [np.ones(n_users, np.float32), np.zeros(pad, np.float32)]
    )
    return {
        "user": jnp.asarray(users.reshape(steps, batch_size)),
        "relevant": jnp.asarray(
            relevant.reshape(steps, batch_size, relevant.shape[1])
        ),
        "n_valid": jnp.asarray(counts.reshape(steps, batch_size)),
        "weight": jnp.asarray(weight.reshape(steps, batch_size)),
    }


# ---------------------------------------------------------------------------
# Brute-force oracle
# ---------------------------------------------------------------------------


def dense_topk(
    params: mf.MFParams,
    user_ids,
    topk: int,
    *,
    t_p=0.0,
    t_q=0.0,
    hist: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Score-everything-then-argsort reference ranking.

    Materializes the full ``(B, n)`` score matrix (deliberately — this is
    the baseline the engine replaces) via the masked XLA formulation and
    takes a *stable* descending argsort, so ties resolve to the lower item
    index exactly like ``jax.lax.top_k`` and the engine's streaming merges.
    With ``t_p == t_q == 0`` this is the dense brute-force oracle: every
    engine path must reproduce its indices bit-for-bit.
    """
    users = jnp.asarray(np.asarray(user_ids, np.int32))
    h = None if hist is None else jnp.asarray(np.asarray(hist)[user_ids])
    scores = mf.predict_all_items(
        params, users, t_p, t_q, use_kernel=False, hist=h
    )
    idx = jnp.argsort(-scores, axis=1)[:, :topk].astype(jnp.int32)
    return (
        np.asarray(jnp.take_along_axis(scores, idx, axis=1)),
        np.asarray(idx),
    )


# ---------------------------------------------------------------------------
# End-to-end evaluation
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RankingReport:
    """Mean ranking metrics over the evaluated users (see module docstring
    for the exact metric definitions)."""

    topk: int
    users: int      # evaluated users (non-empty relevance sets)
    hr: float
    ndcg: float
    recall: float

    def as_dict(self) -> Dict[str, float]:
        """Flat summary for JSON reports (bench_eval, launch smoke jobs)."""
        return {
            "topk": self.topk,
            "users": self.users,
            f"hr_at_{self.topk}": self.hr,
            f"ndcg_at_{self.topk}": self.ndcg,
            f"recall_at_{self.topk}": self.recall,
        }


def report_from_sums(sums: Dict[str, float], topk: int) -> RankingReport:
    """Reduce :func:`ranking_counts`-style metric sums (e.g. the output of
    ``mf.eval_ranking_epoch_scan``) to a mean :class:`RankingReport`."""
    denom = max(sums["weight_sum"], 1.0)
    return RankingReport(
        topk=topk,
        users=int(sums["weight_sum"]),
        hr=sums["hr_sum"] / denom,
        ndcg=sums["ndcg_sum"] / denom,
        recall=sums["recall_sum"] / denom,
    )


def _metrics_over_batches(rank_fn, users, relevant, counts, topk, batch_size):
    """Shared reduction: rank each user batch, accumulate metric sums."""
    sums = {"hr_sum": 0.0, "ndcg_sum": 0.0, "recall_sum": 0.0, "weight_sum": 0.0}
    for lo in range(0, users.size, batch_size):
        hi = min(lo + batch_size, users.size)
        _, idx = rank_fn(users[lo:hi], topk)
        out = _ranking_counts_jit(
            jnp.asarray(np.asarray(idx, np.int32)),
            jnp.asarray(relevant[lo:hi]),
            jnp.asarray(counts[lo:hi]),
        )
        for key in sums:
            sums[key] += float(out[key])
    return report_from_sums(sums, topk)


def _resolve_relevance(ds, relevance, min_rating, max_users, num_users):
    """Relevance triple for the evaluators: the precomputed one, or built
    from ``ds``; either way filtered to ids the model knows."""
    if relevance is not None:
        users, relevant, counts = relevance
    else:
        users, relevant, counts = relevance_from_dataset(
            ds, min_rating=min_rating, max_users=max_users
        )
    known = users < num_users
    return users[known], relevant[known], counts[known]


def evaluate_engine(
    engine,
    ds=None,
    topk: int = 10,
    *,
    mesh=None,
    batch_size: int = 256,
    min_rating: Optional[float] = None,
    max_users: Optional[int] = None,
    relevance: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]] = None,
) -> RankingReport:
    """Ranking metrics of a live :class:`~repro.serving.engine.ServingEngine`.

    Rankings come from the engine's real serving path — ``topk`` (streaming
    or Pallas kernel, per the engine's ``use_kernel``), or ``topk_sharded``
    when ``mesh`` is given — so the measurement includes exactly the pruned
    layouts production requests see.  Metric sums reduce on device
    (:func:`ranking_counts`); only the ``(B, topk)`` id matrix crosses the
    host boundary per batch.  ``relevance`` accepts a precomputed
    :func:`relevance_from_dataset` triple so repeated evaluations (e.g. a
    pruned-vs-dense comparison, or a timed benchmark) pack the holdout once
    instead of re-sorting the dataset per call.
    """
    users, relevant, counts = _resolve_relevance(
        ds, relevance, min_rating, max_users, engine.num_users
    )
    if mesh is not None:
        rank_fn = lambda u, k: engine.topk_sharded(u, k, mesh=mesh)
    else:
        rank_fn = engine.topk
    return _metrics_over_batches(
        rank_fn, users, relevant, counts, topk, batch_size
    )


def evaluate_oracle(
    params: mf.MFParams,
    ds=None,
    topk: int = 10,
    *,
    t_p=0.0,
    t_q=0.0,
    hist: Optional[np.ndarray] = None,
    batch_size: int = 256,
    min_rating: Optional[float] = None,
    max_users: Optional[int] = None,
    relevance: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]] = None,
) -> RankingReport:
    """Ranking metrics of the brute-force reference (:func:`dense_topk`).

    At thresholds 0 this is the dense oracle the engine paths are pinned
    against; at the trained ``(t_p, t_q)`` it isolates what pruning does to
    ranking quality with no serving machinery in the loop.  ``relevance``
    takes a precomputed :func:`relevance_from_dataset` triple, as in
    :func:`evaluate_engine`.
    """
    users, relevant, counts = _resolve_relevance(
        ds, relevance, min_rating, max_users, params.p.shape[0]
    )

    def rank_fn(u, k):
        return dense_topk(params, u, k, t_p=t_p, t_q=t_q, hist=hist)

    return _metrics_over_batches(
        rank_fn, users, relevant, counts, topk, batch_size
    )
