"""Prequential *ranking* evaluation for rating-free streams.

Click/impression streams carry no rating, so the rating-error prequential
loop (:class:`~repro.eval.prequential.PrequentialEvaluator`) cannot score
them — but they support a sharper question: **was the clicked item in the
top-k we actually served?**  :class:`PrequentialRankingEvaluator` answers it
test-then-learn: every incoming :class:`~repro.online.stream.EventBatch` is
first ranked through the *real pruned serving path* (a live
:class:`~repro.serving.engine.ServingEngine` — including whatever snapshot
staleness it carries — or the updater's own pruned forward pass), scored as
HR@K / MRR@K against the event's item, and only then applied as a training
update.  Each event is scored exactly once by a model that has never seen
it.

Cohort segmentation: every event is attributed to the ``new`` or
``established`` cohort by how many stream events its user had *before* this
one (``new_user_events`` boundary) — the cold-start serving quality and the
steady-state serving quality are different numbers, and averaging them
hides exactly the regressions the online updater exists to fix.  Events
naming users/items the serving side does not know yet count as honest
misses in their cohort (the recommendation the user actually got cannot
have contained the item).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import numpy as np

from repro.eval import ranking as ranking_eval
from repro.online.stream import EventBatch, RatingFreeStreamError


@dataclasses.dataclass
class _CohortAccumulator:
    """Lifetime hit/reciprocal-rank sums for one user cohort."""

    events: int = 0
    hits: int = 0
    rr_sum: float = 0.0

    def as_dict(self) -> Dict[str, float]:
        """``{"events", "hit_rate", "mrr"}`` view (NaN when empty)."""
        n = self.events
        return {
            "events": n,
            "hit_rate": self.hits / n if n else float("nan"),
            "mrr": self.rr_sum / n if n else float("nan"),
        }


class _HitWindow:
    """Fixed-capacity 0/1 ring buffer — windowed hit rate over the last
    ``capacity`` scored events."""

    def __init__(self, capacity: int):
        if capacity <= 0:
            raise ValueError(f"window must be positive, got {capacity}")
        self.capacity = capacity
        self._buf = np.zeros(capacity, np.float64)
        self._pos = 0
        self.count = 0

    def extend(self, hits: np.ndarray) -> None:
        n = hits.size
        if n >= self.capacity:
            self._buf[:] = hits[n - self.capacity:]
            self._pos, self.count = 0, self.capacity
            return
        idx = (self._pos + np.arange(n)) % self.capacity
        self._buf[idx] = hits
        self._pos = int((self._pos + n) % self.capacity)
        self.count = min(self.count + n, self.capacity)

    def mean(self) -> float:
        if self.count == 0:
            return float("nan")
        return float(self._buf[: self.count].sum() / self.count)


@dataclasses.dataclass(frozen=True)
class PrequentialRankingStats:
    """One consistent view of the evaluator's accumulators."""

    topk: int
    events: int            # events scored so far
    hit_rate: float        # lifetime HR@K ("served the clicked item")
    mrr: float             # lifetime MRR@K (reciprocal rank, 0 on miss)
    window_hit_rate: float  # HR@K over the last `window` events
    window_events: int
    cohorts: Dict[str, Dict[str, float]]  # "new" / "established" views

    def as_dict(self) -> Dict[str, float]:
        """Flat summary for JSON run reports (cohorts inlined by prefix)."""
        out = {
            "topk": self.topk,
            "events": self.events,
            "hit_rate": self.hit_rate,
            "mrr": self.mrr,
            "window_hit_rate": self.window_hit_rate,
            "window_events": self.window_events,
        }
        for name, view in self.cohorts.items():
            for key, value in view.items():
                out[f"{name}_{key}"] = value
        return out


class PrequentialRankingEvaluator:
    """Test-then-learn top-k evaluation of the pruned serving path.

    ``score(batch)`` ranks each event's user through the serving path and
    checks whether the event's item appears in the served top-``topk``
    (HR@K) and at which position (MRR@K), *before* any update.
    ``consume(batch)`` then applies the batch through the wrapped
    :class:`~repro.online.updater.OnlineUpdater` — converting rating-free
    clicks first via ``update_fn`` (e.g. a
    :func:`repro.workloads.implicit.implicit_event_batch` partial).

    The ranking source, most-production-like first:

    * ``engine`` — a live :class:`~repro.serving.engine.ServingEngine`;
      rankings reflect exactly what was served, including snapshot lag
      between updater and engine;
    * ``rank_fn(users, topk) -> (scores, indices)`` — any custom path
      (e.g. ``topk_sharded`` on a mesh, or a fleet router);
    * neither — the updater's own factors ranked through the pruned
      brute-force pass (:func:`repro.eval.ranking.dense_topk` at the
      updater's live thresholds).

    Ordering guarantee (pinned by ``tests/test_prequential_ranking.py``):
    an event NEVER influences its own ranking — scoring happens strictly
    before the update, so a clicked item absent from the pre-update top-k
    scores a miss even if the update would immediately surface it.
    """

    def __init__(
        self,
        updater=None,
        *,
        engine=None,
        rank_fn: Optional[Callable] = None,
        topk: int = 10,
        window: int = 2048,
        new_user_events: int = 3,
        update_fn: Optional[Callable[[EventBatch], EventBatch]] = None,
    ):
        if topk <= 0:
            raise ValueError(f"topk must be positive, got {topk}")
        if new_user_events <= 0:
            raise ValueError(
                f"new_user_events must be positive, got {new_user_events}"
            )
        if updater is None and engine is None and rank_fn is None:
            raise ValueError(
                "need a ranking source: an updater, an engine, or a rank_fn"
            )
        self.updater = updater
        self.engine = engine
        self.rank_fn = rank_fn
        self.topk = topk
        self.new_user_events = new_user_events
        self.update_fn = update_fn
        self.window = _HitWindow(window)
        self.events = 0
        self._hits = 0
        self._rr_sum = 0.0
        self._cohorts = {
            "new": _CohortAccumulator(),
            "established": _CohortAccumulator(),
        }
        self._seen: Dict[int, int] = {}   # user -> scored events so far

    # -- ranking plumbing ---------------------------------------------------
    def _capacity(self):
        """(num_users, num_items) the ranking source can serve."""
        if self.rank_fn is not None:
            return None, None   # caller-owned: assume it serves everything
        if self.engine is not None:
            return self.engine.num_external, self.engine.n_items
        p = self.updater.params
        return p.p.shape[0], p.q.shape[0]

    def _rank(self, users: np.ndarray) -> np.ndarray:
        """(B, topk) served item indices for the given user rows."""
        if self.rank_fn is not None:
            _, idx = self.rank_fn(users, self.topk)
        elif self.engine is not None:
            _, idx = self.engine.topk(users, self.topk)
        else:
            upd = self.updater
            _, idx = ranking_eval.dense_topk(
                upd.params, users, self.topk,
                t_p=upd.t_p, t_q=upd.t_q,
                hist=upd.user_history,
            )
        return np.asarray(idx)

    # -- scoring ------------------------------------------------------------
    def score(self, batch: EventBatch) -> Dict[str, float]:
        """Score one batch against the CURRENT serving state (no update).

        Returns the batch's own ``{"hit_rate", "mrr", "events"}``; the
        running views live on :attr:`stats`.  Works on rated and
        rating-free batches alike — the rating column is never read.
        """
        n = len(batch)
        if n == 0:
            return {"hit_rate": float("nan"), "mrr": float("nan"),
                    "events": 0}
        users = np.asarray(batch.user, np.int64)
        items = np.asarray(batch.item, np.int64)
        max_u, max_i = self._capacity()
        servable = np.ones(n, bool)
        if max_u is not None:
            servable = (users < max_u) & (items < max_i)

        hits = np.zeros(n, np.float64)
        rr = np.zeros(n, np.float64)
        if servable.any():
            idx = self._rank(users[servable].astype(np.int32))
            pos_mask = idx == items[servable, None]      # (B_s, K)
            hit_rows = pos_mask.any(axis=1)
            first_pos = np.argmax(pos_mask, axis=1)
            hits[servable] = hit_rows.astype(np.float64)
            rr[servable] = np.where(hit_rows, 1.0 / (first_pos + 1.0), 0.0)

        # cohort attribution uses the PRE-batch view of each user's history,
        # processed in stream order so an intra-batch repeat establishes
        for row in range(n):
            u = int(users[row])
            prior = self._seen.get(u, 0)
            cohort = (
                self._cohorts["new"] if prior < self.new_user_events
                else self._cohorts["established"]
            )
            cohort.events += 1
            cohort.hits += int(hits[row])
            cohort.rr_sum += rr[row]
            self._seen[u] = prior + 1

        self.events += n
        self._hits += int(hits.sum())
        self._rr_sum += float(rr.sum())
        self.window.extend(hits)
        return {
            "hit_rate": float(hits.sum() / n),
            "mrr": float(rr.sum() / n),
            "events": n,
        }

    def consume(self, batch: EventBatch) -> Dict[str, float]:
        """Test-then-learn: :meth:`score`, then apply through the updater.

        Rating-free batches require ``update_fn`` (clicks → weighted binary
        preferences); without one this raises
        :class:`~repro.online.stream.RatingFreeStreamError` *after* scoring
        — the evaluation is ranking-only either way.  Returns the batch's
        ranking metrics merged with the updater's step metrics.
        """
        eval_metrics = self.score(batch)
        if self.updater is None or len(batch) == 0:
            return eval_metrics
        update_batch = batch
        if self.update_fn is not None:
            update_batch = self.update_fn(batch)
        elif batch.rating is None:
            raise RatingFreeStreamError(
                "consume() needs ratings to train on; pass update_fn= (e.g. "
                "a repro.workloads.implicit.implicit_event_batch partial) "
                "to convert rating-free clicks into update batches."
            )
        update_metrics = self.updater.apply(update_batch)
        return {**update_metrics, **eval_metrics}

    # -- views --------------------------------------------------------------
    @property
    def stats(self) -> PrequentialRankingStats:
        """Current ranking views (see the class docstring)."""
        n = max(self.events, 1)
        return PrequentialRankingStats(
            topk=self.topk,
            events=self.events,
            hit_rate=self._hits / n,
            mrr=self._rr_sum / n,
            window_hit_rate=self.window.mean(),
            window_events=self.window.count,
            cohorts={
                name: acc.as_dict() for name, acc in self._cohorts.items()
            },
        )
