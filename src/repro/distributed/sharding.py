"""Sharding rules: how every param/input tensor maps onto the production mesh.

Axes: ``data`` (+ ``pod`` when multi-pod) carry batch/row parallelism (DP);
``model`` carries tensor/expert parallelism (TP/EP).  Rules are path-based
functions over param pytrees so they survive structural change (stacked scan
layers get a leading ``None`` automatically).

MF mapping (the paper's model at scale): user rows over the data axes, item
rows over ``model`` — a rating batch sharded over data then gathers its item
rows across ``model``, which is the MF analogue of DP x TP (DESIGN.md §3).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def all_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)


def ns(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def _path_parts(path) -> list:
    parts = []
    for entry in path:
        part = getattr(entry, "key", None)
        if part is None:
            part = getattr(entry, "idx", None)
        if part is None:
            part = getattr(entry, "name", str(entry))
        parts.append(str(part))
    return parts


def tree_shardings(params: Pytree, spec_fn, mesh: Mesh) -> Pytree:
    """Map ``spec_fn(parts, leaf) -> PartitionSpec`` over a pytree."""

    def mk(path, leaf):
        spec = spec_fn(_path_parts(path), leaf)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(mk, params)


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    names = entry if isinstance(entry, (tuple, list)) else (entry,)
    size = 1
    for name in names:
        size *= mesh.shape[name]
    return size


def sanitize_shardings(shardings: Pytree, avals: Pytree) -> Pytree:
    """Downgrade any sharded dim whose size is not divisible by its mesh
    extent to replicated-along-that-dim.

    Assigned-architecture dimensions are published numbers (49155-entry
    vocabs, 2,449,029-node graphs) that owe the mesh no divisibility; this
    keeps every cell lowerable while preserving sharding on the conforming
    dims.  Applied as the single choke point in the dry-run / launchers.
    """

    def fix(sh, aval):
        if not isinstance(sh, NamedSharding):
            return sh
        shape = getattr(aval, "shape", ())
        spec = tuple(sh.spec)
        if len(spec) < len(shape):
            spec = spec + (None,) * (len(shape) - len(spec))
        new_spec = []
        for dim, entry in zip(shape, spec):
            extent = _axis_size(sh.mesh, entry)
            new_spec.append(entry if extent > 1 and dim % extent == 0 else
                            (entry if extent == 1 else None))
        return NamedSharding(sh.mesh, P(*new_spec))

    return jax.tree_util.tree_map(fix, shardings, avals)


# ---------------------------------------------------------------------------
# Transformers
# ---------------------------------------------------------------------------


def transformer_spec(parts, leaf) -> P:
    tp = "model"
    stacked = parts and parts[0] == "layers"
    name = parts[-1]
    parent = parts[-2] if len(parts) >= 2 else ""

    if name == "embed":
        spec = (tp, None)
    elif name == "lm_head":
        spec = (None, tp)
    elif name in ("wq", "wk", "wv", "wkv_a", "wk_b", "wv_b"):
        # wkv_a is small (d x (lora+rope)); sharding its output dim would
        # split the latent that every head needs — keep replicated.
        spec = (None, None) if name == "wkv_a" else (None, tp)
    elif name in ("bq", "bk", "bv"):
        spec = (tp,)
    elif name == "wo" and parent in ("attn", "mlp", "shared"):
        spec = (tp, None)
    elif parent == "moe" and name in ("wg", "wi", "wo"):
        spec = (tp, None, None)  # EP: experts over model axis
    elif name in ("wg", "wi"):
        spec = (None, tp)
    elif name == "router":
        spec = (None, None)
    else:  # norms, scalars, biases of small layers
        spec = tuple(None for _ in range(getattr(leaf, "ndim", 0)))

    if stacked:
        spec = (None,) + tuple(spec)
    return P(*spec)


def transformer_param_shardings(params: Pytree, mesh: Mesh) -> Pytree:
    return tree_shardings(params, transformer_spec, mesh)


def lm_batch_shardings(mesh: Mesh):
    dp = data_axes(mesh)
    return {"tokens": ns(mesh, dp, None), "labels": ns(mesh, dp, None)}


def decode_state_spec_fn(mesh: Mesh, *, shard_seq: bool):
    """KV caches: batch over data axes normally; for batch=1 long-context
    cells the *sequence* axis is sharded instead (SP decode).

    When the KV-head count does not divide the model axis (qwen1.5's 20
    heads on 16-way TP), head sharding would be silently downgraded to
    replication — a 107 GB/device cache at decode_32k.  In that case the
    sequence axis is sharded over "model" instead (flash-decoding-style
    split-S; the softmax reduction turns into a small psum)."""
    dp = data_axes(mesh)
    n_model = mesh.shape["model"]

    def spec_fn(parts, leaf):
        name = parts[-1]
        ndim = getattr(leaf, "ndim", 0)
        if name == "length" or ndim == 0:
            return P()
        # stacked caches: (L, B, S, KH, hd) for GQA, (L, B, S, lora) for MLA;
        # per-layer ('first') caches lack the leading L.
        stacked = "first_caches" not in parts
        lead = (None,) if stacked else ()
        body_ndim = ndim - len(lead)
        if body_ndim == 4:  # (B, S, KH, hd)
            kv_heads = leaf.shape[-2]
            heads_ok = kv_heads % n_model == 0
            if heads_ok:
                spec = (
                    (None, dp, "model", None)
                    if shard_seq
                    else (dp, None, "model", None)
                )
            else:  # split-S decode: sequence over model (and dp when batch=1)
                seq_axes = (dp + ("model",)) if shard_seq else ("model",)
                spec = (
                    (None, seq_axes, None, None)
                    if shard_seq
                    else (dp, seq_axes, None, None)
                )
        elif body_ndim == 3:  # (B, S, lora/rope) — MLA latent, no head axis
            spec = (None, dp, None) if shard_seq else (dp, None, None)
        else:
            spec = tuple(None for _ in range(body_ndim))
        return P(*(lead + tuple(spec)))

    return spec_fn


# ---------------------------------------------------------------------------
# MF (the paper's model)
# ---------------------------------------------------------------------------


def mf_spec_fn(mesh: Mesh):
    dp = data_axes(mesh)

    def spec_fn(parts, leaf):
        name = parts[-1]
        ndim = getattr(leaf, "ndim", 0)
        if name in ("p", "user_bias") or (parts and parts[0] in ("p", "user_bias")):
            return P(dp, None) if ndim == 2 else P(dp)
        if name in ("q", "item_bias", "implicit") or (
            parts and parts[0] in ("q", "item_bias", "implicit")
        ):
            return P("model", None) if ndim == 2 else P("model")
        return P(*(None,) * ndim)

    return spec_fn


def serving_row_multiple(mesh: Mesh) -> int:
    """Batch sizes fed to the sharded serving program must be a multiple of
    the user-axis extent (each data shard takes an equal user slab)."""
    mult = 1
    for axis in data_axes(mesh):
        mult *= mesh.shape[axis]
    return mult


def serving_topk_specs(mesh: Mesh):
    """(in_specs, out_specs) of the engine's sharded top-k program.

    The 2-D serving layout: user rows (and therefore the per-request
    user-factor fan-out) split over the data axes, catalog tiles over
    ``model`` — the serving analogue of the training DP x TP mapping above.
    On a 1-D item-only mesh the user spec degenerates to replicated, which
    is exactly the PR-1 layout.  Outputs are (B, topk) rows sharded like the
    users; the model axis is fully reduced by the in-program all-gather
    merge, so it does not appear in the out specs.
    """
    dp = data_axes(mesh)
    row = dp if dp else None
    user_spec = P(row, None)
    in_specs = (user_spec, P("model", None, None), P("model", None), P("model"))
    out_specs = (user_spec, user_spec)
    return in_specs, out_specs


def serving_topk_kernel_specs(mesh: Mesh):
    """(in_specs, out_specs) of the engine's *kernel-path* sharded top-k.

    Same user/item axis mapping as :func:`serving_topk_specs`, different
    operand set: the Pallas kernel re-masks raw factors per K-block, so each
    shard receives its slab of the padded raw catalog ``(q, r_i, bias)``
    (all row-sharded over "model") plus the replicated ``t_p`` scalar,
    instead of pre-masked streaming tiles.
    """
    dp = data_axes(mesh)
    row = dp if dp else None
    user_spec = P(row, None)
    in_specs = (
        user_spec,            # raw user factor block
        P(),                  # t_p (replicated scalar)
        P("model", None),     # q slab
        P("model", None),     # r_i slab
        P("model", None),     # bias slab
    )
    out_specs = (user_spec, user_spec)
    return in_specs, out_specs


def mf_batch_shardings(mesh: Mesh, has_hist: bool = False):
    dp = data_axes(mesh)
    out = {
        "user": ns(mesh, dp),
        "item": ns(mesh, dp),
        "rating": ns(mesh, dp),
    }
    if has_hist:
        out["hist"] = ns(mesh, dp, None)
    return out


def route_batch_to_owner_shards(
    users,
    items,
    ratings,
    *,
    num_users: int,
    n_dp: int,
    weight=None,
    pad_to_pow2: bool = False,
):
    """Reorder a rating batch to satisfy the owner-compute contract.

    ``mf.train_step_shard_map`` splits the batch positionally into ``n_dp``
    contiguous chunks and requires chunk ``s`` to contain only users owned by
    data shard ``s`` (``u // m_loc == s``).  This host-side router buckets
    the rows by owner and pads every bucket to a common length with
    weight-0 rows (user = the shard's first owned row, item 0, rating 0) —
    fully inert under the step's weight gate, so arbitrary event batches
    (the online updater's input) can ride the sharded step.

    ``pad_to_pow2`` rounds the per-shard length up to a power of two so a
    jitted caller sees O(log B) distinct shapes, the same trick as the
    serving micro-batcher.  Returns a numpy batch dict incl. ``"weight"``.
    """
    if num_users % n_dp:
        raise ValueError(
            f"num_users ({num_users}) must divide over {n_dp} data shards"
        )
    users = np.asarray(users, np.int32)
    items = np.asarray(items, np.int32)
    ratings = np.asarray(ratings, np.float32)
    if users.size and (users.min() < 0 or users.max() >= num_users):
        raise ValueError(
            f"user ids must lie in [0, {num_users}) — grow the tables first "
            f"(got range [{users.min()}, {users.max()}])"
        )
    m_loc = num_users // n_dp
    owner = users // m_loc
    buckets = [np.nonzero(owner == s)[0] for s in range(n_dp)]
    length = max(1, max(len(b) for b in buckets))
    if pad_to_pow2:
        length = 1 << (length - 1).bit_length()
    out = {
        "user": np.empty(n_dp * length, np.int32),
        "item": np.zeros(n_dp * length, np.int32),
        "rating": np.zeros(n_dp * length, np.float32),
        "weight": np.zeros(n_dp * length, np.float32),
    }
    for s, idx in enumerate(buckets):
        base = s * length
        out["user"][base : base + length] = s * m_loc  # inert padding rows
        out["user"][base : base + len(idx)] = users[idx]
        out["item"][base : base + len(idx)] = items[idx]
        out["rating"][base : base + len(idx)] = ratings[idx]
        out["weight"][base : base + len(idx)] = (
            1.0 if weight is None else np.asarray(weight, np.float32)[idx]
        )
    return out


# ---------------------------------------------------------------------------
# GNN
# ---------------------------------------------------------------------------


def gnn_spec_fn(mesh: Mesh):
    def spec_fn(parts, leaf):
        return P(*(None,) * getattr(leaf, "ndim", 0))  # GAT weights are tiny

    return spec_fn


def gnn_batch_shardings(mesh: Mesh):
    flat = all_axes(mesh)
    dp = data_axes(mesh)
    return {
        "features": ns(mesh, dp, None),   # nodes over data axes
        "edges": ns(mesh, flat, None),    # edges over the whole device grid
        "edge_mask": ns(mesh, flat),
        "labels": ns(mesh, dp),
    }


# ---------------------------------------------------------------------------
# RecSys
# ---------------------------------------------------------------------------

_REPLICATE_BELOW_ROWS = 8192  # small tables are cheaper replicated


def recsys_spec_fn(mesh: Mesh):
    flat = all_axes(mesh)

    def spec_fn(parts, leaf):
        name_chain = "/".join(parts)
        ndim = getattr(leaf, "ndim", 0)
        is_table = any(
            key in name_chain for key in ("tables", "item_embed", "v", "w")
        ) and ndim in (1, 2)
        if "tables" in parts or parts[-1] in ("item_embed", "v"):
            if leaf.shape[0] >= _REPLICATE_BELOW_ROWS:
                return P(flat, None) if ndim == 2 else P(flat)
            return P(*(None,) * ndim)
        if parts[-1] == "w" and ndim == 1 and leaf.shape[0] >= _REPLICATE_BELOW_ROWS:
            return P(flat)  # FM linear term over the same rows as `v`
        del is_table
        return P(*(None,) * ndim)  # MLPs / norms / blocks replicated

    return spec_fn


def recsys_batch_shardings(mesh: Mesh, batch: dict):
    dp = data_axes(mesh)

    def spec(name, arr):
        nd = getattr(arr, "ndim", 0)
        if nd == 0:
            return ns(mesh)
        return ns(mesh, dp, *([None] * (nd - 1)))

    return {name: spec(name, arr) for name, arr in batch.items()}
