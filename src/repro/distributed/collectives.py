"""Collective helpers: microbatched gradient accumulation (compute/comm
overlap) and HLO collective-byte accounting support.

``microbatch_grads`` splits a global batch into ``n_micro`` slices scanned
sequentially: peak activation memory drops by ~n_micro and, under SPMD, the
per-microbatch reduce-scatters overlap with the next microbatch's compute —
the standard overlap trick, expressed in jax.lax rather than NCCL streams.
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

Pytree = Any


def microbatch_grads(
    loss_fn: Callable[[Pytree, Dict[str, jax.Array]], jax.Array],
    params: Pytree,
    batch: Dict[str, jax.Array],
    n_micro: int,
):
    """Mean loss + grads accumulated over ``n_micro`` sequential microbatches.

    Every array in ``batch`` is split along axis 0; n_micro must divide the
    global batch.
    """
    if n_micro <= 1:
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        return loss, grads

    def reshape(x):
        b = x.shape[0]
        return x.reshape((n_micro, b // n_micro) + x.shape[1:])

    micro = {k: reshape(v) for k, v in batch.items()}
    zero_grads = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )

    def body(carry, mb):
        loss_acc, grad_acc = carry
        loss, grads = jax.value_and_grad(loss_fn)(params, mb)
        grad_acc = jax.tree_util.tree_map(
            lambda a, g: a + g.astype(jnp.float32), grad_acc, grads
        )
        return (loss_acc + loss, grad_acc), None

    (loss_sum, grad_sum), _ = jax.lax.scan(
        body, (jnp.float32(0.0), zero_grads), micro
    )
    inv = 1.0 / n_micro
    return loss_sum * inv, jax.tree_util.tree_map(lambda g: g * inv, grad_sum)
