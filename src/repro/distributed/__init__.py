from repro.distributed.collectives import microbatch_grads  # noqa: F401
from repro.distributed.compression import (  # noqa: F401
    compress_with_feedback,
    compressed_psum,
    dequantize_int8,
    init_error_feedback,
    quantize_int8,
)
from repro.distributed.mesh_compat import (  # noqa: F401
    get_abstract_mesh,
    resolve_mesh,
    use_mesh,
)
from repro.distributed.fault_tolerance import (  # noqa: F401
    FailureInjector,
    StepFailure,
    StragglerDetector,
    run_with_retries,
)
from repro.distributed.sharding import (  # noqa: F401
    data_axes,
    all_axes,
    gnn_batch_shardings,
    gnn_spec_fn,
    lm_batch_shardings,
    mf_batch_shardings,
    mf_spec_fn,
    recsys_batch_shardings,
    recsys_spec_fn,
    transformer_param_shardings,
    transformer_spec,
    tree_shardings,
    decode_state_spec_fn,
)
