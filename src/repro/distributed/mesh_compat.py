"""Version-compat shims for jax's moving mesh / shard_map API surface.

The mesh entry points this repo relies on were renamed or relocated across
jax releases:

* ``jax.sharding.get_abstract_mesh`` / ``jax.sharding.set_mesh`` exist only
  on newer jax; older releases express the ambient mesh through the classic
  ``with mesh:`` context (``thread_resources.env.physical_mesh``).
* ``jax.shard_map`` (kwarg ``check_vma``) replaced
  ``jax.experimental.shard_map.shard_map`` (kwarg ``check_rep``).
* On jax < 0.5 lowering a shard_map against an ``AbstractMesh`` under jit is
  miscompiled by the partitioner ("sharding-remover" RET_CHECK), so abstract
  meshes are resolved to the ambient *concrete* mesh before use.

Every mesh-context / shard_map call site in this repo goes through this
module; feature probing (never version string parsing) keeps it working on
both sides of each rename.
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable, Optional

import jax

try:  # public since 0.4.x
    from jax.sharding import AbstractMesh as _AbstractMesh
except ImportError:  # pragma: no cover - ancient jax
    _AbstractMesh = ()


def _nonempty(mesh) -> bool:
    return mesh is not None and bool(getattr(mesh, "axis_names", ()))


def _ambient_concrete_mesh():
    """The mesh installed by the classic ``with mesh:`` context, if any."""
    try:
        from jax._src import mesh as _mesh_lib

        physical = _mesh_lib.thread_resources.env.physical_mesh
    except Exception:  # pragma: no cover - internals moved
        return None
    return physical if _nonempty(physical) and not physical.empty else None


def get_abstract_mesh():
    """Ambient mesh or ``None``.

    Returns whatever the running jax considers "the mesh in scope": the
    abstract mesh from ``jax.sharding.set_mesh`` on new jax, or the concrete
    mesh from a ``with mesh:`` / :func:`use_mesh` context on old jax.  An
    empty/unset mesh normalizes to ``None`` so callers can fall back.
    """
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        mesh = getter()
        if _nonempty(mesh):
            return mesh
    return _ambient_concrete_mesh()


def resolve_mesh(mesh=None):
    """Normalize a caller-supplied mesh (or None) to something lowerable.

    ``None`` resolves to the ambient mesh.  On jax without native
    ``jax.shard_map`` an ``AbstractMesh`` is swapped for the ambient concrete
    mesh with the same axis names (abstract lowering is broken there); when
    no matching concrete mesh is in scope the abstract mesh is returned
    unchanged and jax reports its own error.
    """
    if mesh is None:
        return get_abstract_mesh()
    if not hasattr(jax, "shard_map") and isinstance(mesh, _AbstractMesh):
        ambient = _ambient_concrete_mesh()
        if ambient is not None and tuple(ambient.axis_names) == tuple(
            mesh.axis_names
        ):
            return ambient
    return mesh


@contextlib.contextmanager
def use_mesh(mesh):
    """Cross-version ``jax.sharding.set_mesh``: installs ``mesh`` as the
    ambient mesh for the dynamic extent of the block."""
    setter = getattr(jax.sharding, "set_mesh", None) or getattr(
        jax.sharding, "use_mesh", None
    )
    if setter is not None:
        with setter(mesh):
            yield mesh
    else:  # classic thread_resources context
        with mesh:
            yield mesh


def shard_map(
    f: Callable[..., Any],
    *,
    mesh=None,
    in_specs,
    out_specs,
    check_vma: bool = True,
):
    """Cross-version ``jax.shard_map`` (new) / ``shard_map`` (experimental).

    ``check_vma`` maps onto the old API's ``check_rep``.  The mesh is passed
    through :func:`resolve_mesh` first, so callers may hand in ``None`` (use
    ambient), a concrete ``Mesh``, or an ``AbstractMesh``.
    """
    mesh = resolve_mesh(mesh)
    native = getattr(jax, "shard_map", None)
    if native is not None:
        try:
            return native(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_vma=check_vma,
            )
        except TypeError:  # jax that renamed the kwarg but not the module
            return native(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_rep=check_vma,
            )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )
