"""Fault tolerance: bounded step retries + straggler detection.

This container has one CPU device, so node failure and stragglers are
*simulated* at the driver layer — but the mechanisms are the real ones a
multi-pod deployment uses: bounded retry with backoff around the step
call (``run_with_retries``; wired around the trainer's slab loop via
``TrainConfig.max_step_retries`` and around whole epochs in
``launch/train.py``) and per-step timing outlier detection
(``StragglerDetector``; slab timings feed the trainer's epoch records).
``FailureInjector`` drives the regression tests for both.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Deque, Optional, Tuple


class StepFailure(RuntimeError):
    """Raised by the step wrapper after exhausting retries."""


def run_with_retries(
    step_fn: Callable[..., Any],
    *args,
    max_retries: int = 3,
    backoff_s: float = 0.5,
    on_retry: Optional[Callable[[int, BaseException], None]] = None,
    **kwargs,
):
    """Execute a (re-entrant, functional) step with bounded retries.

    Works because steps are pure functions of (params, batch): a failed
    attempt has no side effects to roll back — re-issuing the same call is
    always safe.  Transient XLA/runtime errors (preempted donations, OOM
    races on rescheduled pods) are the target; assertion-style errors
    propagate immediately.
    """
    attempt = 0
    while True:
        try:
            return step_fn(*args, **kwargs)
        except (AssertionError, TypeError, ValueError):
            raise  # programming errors — retrying cannot help
        except BaseException as exc:  # noqa: BLE001 — runtime faults
            attempt += 1
            if attempt > max_retries:
                raise StepFailure(
                    f"step failed after {max_retries} retries: {exc!r}"
                ) from exc
            if on_retry is not None:
                on_retry(attempt, exc)
            time.sleep(backoff_s * (2 ** (attempt - 1)))


@dataclasses.dataclass
class StragglerDetector:
    """Flags steps whose duration is a z-score outlier over a rolling window.

    Deployment policy (documented for the launcher): a flagged worker is
    first given a soft warning; persistent flags trigger requesting a backup
    worker from the scheduler and excluding the straggler at the next
    checkpoint boundary — the standard backup-task mitigation.
    """

    window: int = 50
    z_threshold: float = 4.0
    min_samples: int = 10
    _times: Deque[float] = dataclasses.field(default_factory=deque)
    flagged: int = 0

    def record(self, duration_s: float) -> bool:
        times = self._times
        is_straggler = False
        if len(times) >= self.min_samples:
            mean = sum(times) / len(times)
            var = sum((t - mean) ** 2 for t in times) / len(times)
            std = max(var ** 0.5, 1e-9)
            if (duration_s - mean) / std > self.z_threshold:
                is_straggler = True
                self.flagged += 1
        times.append(duration_s)
        if len(times) > self.window:
            times.popleft()
        return is_straggler


class FailureInjector:
    """Deterministic fault injection for integration tests: raises on the
    configured step numbers, then succeeds on retry."""

    def __init__(self, fail_on_steps: Tuple[int, ...]):
        self.fail_on_steps = set(fail_on_steps)
        self.calls = 0
        self.failures = 0

    def __call__(self, step: int) -> None:
        self.calls += 1
        if step in self.fail_on_steps:
            self.fail_on_steps.discard(step)
            self.failures += 1
            raise RuntimeError(f"injected fault at step {step}")
