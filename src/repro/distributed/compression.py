"""Gradient compression with error feedback (1-bit-Adam / EF-SGD family).

int8 uniform quantization with a per-tensor scale; the quantization residual
is carried to the next step (error feedback), which is what keeps SGD-family
convergence unharmed (Karimireddy et al., 2019).  Inside ``shard_map`` the
quantized int32 payload is what crosses the ICI — an 4x reduction of the
gradient all-reduce bytes, directly targeting the collective roofline term.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(grads: Pytree) -> Pytree:
    return jax.tree_util.tree_map(lambda g: jnp.zeros_like(g, jnp.float32), grads)


def compress_with_feedback(
    grads: Pytree, residual: Pytree
) -> Tuple[Pytree, Pytree]:
    """Returns (dequantized-compressed grads, new residual).

    The returned grads are exactly what the receiving side reconstructs, so
    the optimizer sees the post-compression values and the residual absorbs
    the difference.
    """

    def one(g, r):
        target = g.astype(jnp.float32) + r
        q, scale = quantize_int8(target)
        recon = dequantize_int8(q, scale)
        return recon, target - recon

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (
        treedef.unflatten([o[0] for o in out]),
        treedef.unflatten([o[1] for o in out]),
    )


def compressed_psum(grads: Pytree, axis_name: str) -> Pytree:
    """All-reduce int8-quantized gradients inside ``shard_map``.

    All shards agree on a COMMON scale (pmax of local maxima — one scalar
    psum) and quantize to it, so the int8 sum is exactly the sum of the
    quantized values: error <= scale/2 per element per shard, with no
    mean-scale bias when shard magnitudes differ (e.g. owner-compute partials
    where most shards contribute zeros).  Payload crossing the links is int8
    + one scalar: ~4x fewer bytes than the f32 psum.
    """

    def one(g):
        g = g.astype(jnp.float32)
        local_max = jnp.max(jnp.abs(g))
        scale = jnp.maximum(jax.lax.pmax(local_max, axis_name), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return summed.astype(jnp.float32) * scale

    return jax.tree_util.tree_map(one, grads)
