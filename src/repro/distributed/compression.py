"""Payload compression for cross-process/cross-host replication traffic.

Two families, picked by what the receiver is allowed to lose:

* **Lossy gradient compression with error feedback** (1-bit-Adam / EF-SGD
  family): int8 uniform quantization with a per-tensor scale; the
  quantization residual is carried to the next step (error feedback), which
  is what keeps SGD-family convergence unharmed (Karimireddy et al., 2019).
  Inside ``shard_map`` the quantized int32 payload is what crosses the ICI —
  a 4x reduction of the gradient all-reduce bytes, directly targeting the
  collective roofline term.

* **Lossless array compression** (:func:`compress_array` /
  :func:`decompress_array`): byte-shuffle + DEFLATE.  Transposing an array's
  bytes so all the sign/exponent bytes sit together (the blosc "shuffle"
  filter) makes float32 factor rows highly compressible — exponents of
  trained factors cluster tightly — while the round trip stays **bit-exact**.
  This is the codec the serving fleet's delta replication uses
  (``serving/fleet/bus.py``): replicas must converge bitwise to the
  published snapshot, so quantization is off the table there.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


# ---------------------------------------------------------------------------
# Lossless codec (delta replication payloads)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CompressedArray:
    """One losslessly compressed ndarray: ``data`` is the DEFLATE stream of
    the byte-shuffled buffer (or the raw buffer when ``codec="raw"`` — tiny
    arrays skip the filter), plus the shape/dtype needed to reconstruct."""

    data: bytes
    shape: Tuple[int, ...]
    dtype: str
    codec: str = "shuffle-zlib"

    @property
    def nbytes(self) -> int:
        """Compressed payload size (what crosses the wire)."""
        return len(self.data)

    @property
    def raw_nbytes(self) -> int:
        """Uncompressed size of the array this reconstructs to."""
        return int(np.prod(self.shape, dtype=np.int64)) * np.dtype(self.dtype).itemsize


def compress_array(x, *, level: int = 6, min_bytes: int = 128) -> CompressedArray:
    """Losslessly compress an array (bit-exact round trip guaranteed).

    The buffer is byte-shuffled — viewed as ``(n_elems, itemsize)`` uint8 and
    transposed — so each byte lane (sign/exponent/mantissa for floats)
    compresses as its own run, then DEFLATE'd.  Arrays under ``min_bytes``
    are stored raw: the zlib header would cost more than it saves.
    """
    # shape before ascontiguousarray: it promotes 0-d scalars to (1,)
    shape = tuple(np.shape(x))
    arr = np.ascontiguousarray(np.asarray(x))
    if arr.nbytes < min_bytes:
        return CompressedArray(arr.tobytes(), shape, arr.dtype.str, codec="raw")
    itemsize = arr.dtype.itemsize
    shuffled = (
        arr.view(np.uint8).reshape(-1, itemsize).T.tobytes()
        if itemsize > 1
        else arr.tobytes()
    )
    return CompressedArray(zlib.compress(shuffled, level), shape, arr.dtype.str)


def decompress_array(c: CompressedArray) -> np.ndarray:
    """Invert :func:`compress_array`; the result is bitwise identical to the
    array that was compressed."""
    dtype = np.dtype(c.dtype)
    if c.codec == "raw":
        return np.frombuffer(c.data, dtype).reshape(c.shape).copy()
    if c.codec != "shuffle-zlib":
        raise ValueError(f"unknown codec {c.codec!r}")
    flat = np.frombuffer(zlib.decompress(c.data), np.uint8)
    if dtype.itemsize > 1:
        flat = flat.reshape(dtype.itemsize, -1).T.reshape(-1).copy()
    return flat.view(dtype).reshape(c.shape).copy()


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(grads: Pytree) -> Pytree:
    return jax.tree_util.tree_map(lambda g: jnp.zeros_like(g, jnp.float32), grads)


def compress_with_feedback(
    grads: Pytree, residual: Pytree
) -> Tuple[Pytree, Pytree]:
    """Returns (dequantized-compressed grads, new residual).

    The returned grads are exactly what the receiving side reconstructs, so
    the optimizer sees the post-compression values and the residual absorbs
    the difference.
    """

    def one(g, r):
        target = g.astype(jnp.float32) + r
        q, scale = quantize_int8(target)
        recon = dequantize_int8(q, scale)
        return recon, target - recon

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (
        treedef.unflatten([o[0] for o in out]),
        treedef.unflatten([o[1] for o in out]),
    )


def compressed_psum(grads: Pytree, axis_name: str) -> Pytree:
    """All-reduce int8-quantized gradients inside ``shard_map``.

    All shards agree on a COMMON scale (pmax of local maxima — one scalar
    psum) and quantize to it, so the int8 sum is exactly the sum of the
    quantized values: error <= scale/2 per element per shard, with no
    mean-scale bias when shard magnitudes differ (e.g. owner-compute partials
    where most shards contribute zeros).  Payload crossing the links is int8
    + one scalar: ~4x fewer bytes than the f32 psum.
    """

    def one(g):
        g = g.astype(jnp.float32)
        local_max = jnp.max(jnp.abs(g))
        scale = jnp.maximum(jax.lax.pmax(local_max, axis_name), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return summed.astype(jnp.float32) * scale

    return jax.tree_util.tree_map(one, grads)
