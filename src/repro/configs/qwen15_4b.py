"""qwen1.5-4b [hf:Qwen/Qwen1.5-*]: 40L d2560 20H (kv=20) d_ff=6912,
vocab 151936, QKV bias, head_dim 128."""
import jax.numpy as jnp

from repro.configs import base
from repro.models.transformer import TransformerConfig

ARCH_ID = "qwen1.5-4b"

CONFIG = TransformerConfig(
    name=ARCH_ID,
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab_size=151936,
    activation="swiglu",
    qkv_bias=True,
    tie_embeddings=False,
    rope_theta=5e6,
)


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        activation="swiglu",
        qkv_bias=True,
        tie_embeddings=False,
        dtype=jnp.float32,
        attn_chunk=8,
    )


def cells():
    return base.lm_cells(ARCH_ID, CONFIG)
