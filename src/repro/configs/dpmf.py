"""dpmf — the paper's own architecture at production scale.

FunkSVD factorization of a 100M-user x 10M-item rating matrix at k=128,
trained with dynamically-pruned minibatch SGD/Adagrad (the paper's full
pipeline), user rows sharded over the data axes and item rows over "model"
(DESIGN.md §3).  Not one of the 10 assigned archs — it is the paper's model
itself, included per the deliverables ("+ paper's own")."""
import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.configs import base
from repro.core import mf
from repro.distributed import sharding as shd
from repro.optim.optimizers import RowOptimizer

ARCH_ID = "dpmf"


@dataclasses.dataclass(frozen=True)
class DPMFConfig:
    name: str = ARCH_ID
    num_users: int = 100_000_000
    num_items: int = 10_000_000
    k: int = 128
    lam: float = 0.02
    lr: float = 0.05
    optimizer: str = "adagrad"
    pruning_rate: float = 0.3


CONFIG = DPMFConfig()


def smoke_config() -> DPMFConfig:
    return DPMFConfig(name=ARCH_ID + "-smoke", num_users=200, num_items=150, k=16)


def _train_cell(batch: int) -> base.CellSpec:
    cfg = CONFIG
    opt = RowOptimizer(name=cfg.optimizer)

    def init(rng):
        return mf.init_params(rng, cfg.num_users, cfg.num_items, cfg.k)

    dim_mask = jnp.ones((cfg.k,), jnp.float32)

    def step(params, opt_state, batch_d, t_p, t_q):
        return mf.train_step(
            params, opt_state, batch_d, t_p, t_q, jnp.float32(cfg.lr), dim_mask,
            opt=opt, lam=cfg.lam,
        )

    a_params = base.abstract_like(init, jax.random.PRNGKey(0))
    a_opt = base.abstract_like(functools.partial(mf.init_opt_state, opt=opt), a_params)
    a_batch = {
        "user": jax.ShapeDtypeStruct((batch,), jnp.int32),
        "item": jax.ShapeDtypeStruct((batch,), jnp.int32),
        "rating": jax.ShapeDtypeStruct((batch,), jnp.float32),
    }
    a_scalar = jax.ShapeDtypeStruct((), jnp.float32)

    def in_shardings(mesh):
        spec_fn = shd.mf_spec_fn(mesh)
        p_sh = shd.tree_shardings(a_params, spec_fn, mesh)
        # MFOptState paths start with the same field names (p/q/...) so the
        # same spec function shards the accumulators like their tables.
        o_sh = shd.tree_shardings(a_opt, spec_fn, mesh)
        b_sh = shd.mf_batch_shardings(mesh)
        return (p_sh, o_sh, b_sh, shd.replicated(mesh), shd.replicated(mesh))

    return base.CellSpec(
        arch=ARCH_ID,
        shape_id=f"train_{batch // 1024}k",
        kind="train",
        step_fn=step,
        abstract_args=(a_params, a_opt, a_batch, a_scalar, a_scalar),
        in_shardings=in_shardings,
        donate_argnums=(0, 1),
        note="paper's DP-MF minibatch step: gather -> pruned dot -> masked update",
    )


def _serve_cell(batch: int) -> base.CellSpec:
    cfg = CONFIG

    def init(rng):
        return mf.init_params(rng, cfg.num_users, cfg.num_items, cfg.k)

    def step(params, users, t_p, t_q):
        h = params.p[users]
        from repro.core.ranks import mask_rows

        scores = jnp.einsum(
            "bk,nk->bn", mask_rows(h, t_p), mask_rows(params.q, t_q)
        )
        return jax.lax.top_k(scores, 100)

    a_params = base.abstract_like(init, jax.random.PRNGKey(0))
    a_users = jax.ShapeDtypeStruct((batch,), jnp.int32)
    a_scalar = jax.ShapeDtypeStruct((), jnp.float32)

    def in_shardings(mesh):
        p_sh = shd.tree_shardings(a_params, shd.mf_spec_fn(mesh), mesh)
        return (p_sh, shd.ns(mesh, shd.data_axes(mesh)),
                shd.replicated(mesh), shd.replicated(mesh))

    return base.CellSpec(
        arch=ARCH_ID,
        shape_id=f"serve_top100_{batch}",
        kind="serve",
        step_fn=step,
        abstract_args=(a_params, a_users, a_scalar, a_scalar),
        in_shardings=in_shardings,
        note="pruned full-catalog scoring (paper's 'matrix multiplication' stage)",
    )


def _train_cell_owner_compute(batch: int, compress: bool = False) -> base.CellSpec:
    """Beyond-paper §Perf cell: owner-compute shard_map step (bit-exact to
    train_1m; collectives reduced ~10x — see core/mf.train_step_shard_map).
    ``compress`` additionally int8-quantizes the cross-link payloads."""
    cfg = CONFIG
    opt = RowOptimizer(name=cfg.optimizer)

    def init(rng):
        return mf.init_params(rng, cfg.num_users, cfg.num_items, cfg.k)

    def step(params, opt_state, batch_d, t_p, t_q):
        return mf.train_step_shard_map(
            params, opt_state, batch_d, t_p, t_q,
            lr=cfg.lr, lam=cfg.lam, opt_name=cfg.optimizer,
            compress_grads=compress,
        )

    a_params = base.abstract_like(init, jax.random.PRNGKey(0))
    a_opt = base.abstract_like(functools.partial(mf.init_opt_state, opt=opt), a_params)
    a_batch = {
        "user": jax.ShapeDtypeStruct((batch,), jnp.int32),
        "item": jax.ShapeDtypeStruct((batch,), jnp.int32),
        "rating": jax.ShapeDtypeStruct((batch,), jnp.float32),
    }
    a_scalar = jax.ShapeDtypeStruct((), jnp.float32)

    def in_shardings(mesh):
        spec_fn = shd.mf_spec_fn(mesh)
        return (
            shd.tree_shardings(a_params, spec_fn, mesh),
            shd.tree_shardings(a_opt, spec_fn, mesh),
            shd.mf_batch_shardings(mesh),
            shd.replicated(mesh),
            shd.replicated(mesh),
        )

    return base.CellSpec(
        arch=ARCH_ID,
        shape_id=f"train_{batch // 1024}k_sm" + ("c" if compress else ""),
        kind="train",
        step_fn=step,
        abstract_args=(a_params, a_opt, a_batch, a_scalar, a_scalar),
        in_shardings=in_shardings,
        donate_argnums=(0, 1),
        note="owner-compute shard_map DP-MF step (§Perf; batch routed by user shard)",
    )


def cells():
    return {
        "train_1m": lambda: _train_cell(1_048_576),
        "train_1m_sm": lambda: _train_cell_owner_compute(1_048_576),
        "train_1m_smc": lambda: _train_cell_owner_compute(1_048_576, compress=True),
        "serve_top100": lambda: _serve_cell(1024),
    }
