"""qwen3-4b [hf:Qwen/Qwen3-*]: 36L d2560 32H (GQA kv=8) d_ff=9728,
vocab 151936, per-head qk RMS-norm, head_dim 128."""
import jax.numpy as jnp

from repro.configs import base
from repro.models.transformer import TransformerConfig

ARCH_ID = "qwen3-4b"

CONFIG = TransformerConfig(
    name=ARCH_ID,
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151936,
    activation="swiglu",
    qk_norm=True,
    tie_embeddings=True,
    rope_theta=1e6,
)


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        activation="swiglu",
        qk_norm=True,
        tie_embeddings=True,
        dtype=jnp.float32,
        attn_chunk=8,
    )


def cells():
    return base.lm_cells(ARCH_ID, CONFIG)
