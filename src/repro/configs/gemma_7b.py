"""gemma-7b [arXiv:2403.08295]: 28L d3072 16H (kv=16) d_ff=24576 GeGLU,
head_dim=256, vocab 256000, tied embeddings scaled by sqrt(d)."""
import jax.numpy as jnp

from repro.configs import base
from repro.models.transformer import TransformerConfig

ARCH_ID = "gemma-7b"

CONFIG = TransformerConfig(
    name=ARCH_ID,
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    activation="geglu",
    embed_scale=True,
    tie_embeddings=True,
    rope_theta=10000.0,
)


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        activation="geglu",
        embed_scale=True,
        tie_embeddings=True,
        dtype=jnp.float32,
        attn_chunk=8,
    )


def cells():
    return base.lm_cells(ARCH_ID, CONFIG)
