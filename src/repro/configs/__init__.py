"""Architecture registry: the 10 assigned archs + the paper's own (dpmf).

``build_cell(arch, shape)`` materializes a CellSpec (step fn + abstract
inputs + shardings); ``all_cells()`` enumerates the full dry-run matrix.
"""
from __future__ import annotations

import importlib
from typing import Dict, List, Tuple

_ARCH_MODULES = {
    "gemma-7b": "repro.configs.gemma_7b",
    "qwen1.5-4b": "repro.configs.qwen15_4b",
    "qwen3-4b": "repro.configs.qwen3_4b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite",
    "granite-moe-1b-a400m": "repro.configs.granite_moe",
    "gat-cora": "repro.configs.gat_cora",
    "fm": "repro.configs.fm_arch",
    "sasrec": "repro.configs.sasrec_arch",
    "bst": "repro.configs.bst_arch",
    "dlrm-mlperf": "repro.configs.dlrm_mlperf",
    "dpmf": "repro.configs.dpmf",
}

ASSIGNED_ARCHS: Tuple[str, ...] = tuple(
    a for a in _ARCH_MODULES if a != "dpmf"
)
ALL_ARCHS: Tuple[str, ...] = tuple(_ARCH_MODULES)


def get_module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch])


def get_config(arch: str):
    return get_module(arch).CONFIG


def get_smoke_config(arch: str):
    return get_module(arch).smoke_config()


def shape_ids(arch: str) -> List[str]:
    return list(get_module(arch).cells().keys())


def build_cell(arch: str, shape_id: str):
    builders = get_module(arch).cells()
    if shape_id not in builders:
        raise KeyError(
            f"unknown shape {shape_id!r} for {arch!r}; known: {sorted(builders)}"
        )
    return builders[shape_id]()


def all_cells(include_dpmf: bool = True) -> List[Tuple[str, str]]:
    archs = ALL_ARCHS if include_dpmf else ASSIGNED_ARCHS
    return [(arch, sid) for arch in archs for sid in shape_ids(arch)]
