"""gat-cora [arXiv:1710.10903]: 2-layer GAT, 8 heads x d_hidden 8, attn
aggregation.  Each shape cell carries its own graph stats (and thus d_feat /
n_classes), per the assignment:

  full_graph_sm : Cora      (2,708 nodes / 10,556 edges / 1,433 feats / 7 cls)
  minibatch_lg  : Reddit    (232,965 / 114.6M) sampled with fanout 15-10 from
                  1,024 seed nodes -> padded subgraph (the sampler is real:
                  data/graphs.neighbor_sample)
  ogb_products  : ogbn-products (2,449,029 / 61.9M / 100 feats / 47 cls)
  molecule      : 128 block-diagonally batched 30-node/64-edge graphs
"""
from repro.configs import base
from repro.models.gnn import GATConfig

ARCH_ID = "gat-cora"

CONFIG = GATConfig(
    name=ARCH_ID, d_feat=1433, n_classes=7, n_layers=2, d_hidden=8, n_heads=8
)

# minibatch_lg: 1,024 seeds, fanout (15, 10) -> <= 1024*(1+15+150) nodes and
# 1024*(15+150) edges; padded to these static maxima.
_MB_NODES = 1024 * (1 + 15 + 150)
_MB_EDGES = 1024 * (15 + 150)


def smoke_config() -> GATConfig:
    return GATConfig(
        name=ARCH_ID + "-smoke", d_feat=32, n_classes=5, n_layers=2,
        d_hidden=8, n_heads=4,
    )


def cells():
    return {
        "full_graph_sm": lambda: base.gnn_train_cell(
            ARCH_ID,
            "full_graph_sm",
            CONFIG,
            num_nodes=2708,
            num_edges=10556,
        ),
        "minibatch_lg": lambda: base.gnn_train_cell(
            ARCH_ID,
            "minibatch_lg",
            GATConfig(
                name=ARCH_ID, d_feat=602, n_classes=41, n_layers=2,
                d_hidden=8, n_heads=8,
            ),
            num_nodes=_MB_NODES,
            num_edges=_MB_EDGES,
            with_edge_mask=True,
            note="fanout-(15,10) sampled subgraph from 1,024 seeds; sampler in data/graphs.py",
        ),
        "ogb_products": lambda: base.gnn_train_cell(
            ARCH_ID,
            "ogb_products",
            GATConfig(
                name=ARCH_ID, d_feat=100, n_classes=47, n_layers=2,
                d_hidden=8, n_heads=8,
            ),
            num_nodes=2449029,
            num_edges=61859140,
        ),
        "molecule": lambda: base.gnn_train_cell(
            ARCH_ID,
            "molecule",
            GATConfig(
                name=ARCH_ID, d_feat=32, n_classes=8, n_layers=2,
                d_hidden=8, n_heads=8,
            ),
            num_nodes=128 * 30,
            num_edges=128 * 64,
            with_edge_mask=True,
            note="128 block-diagonal molecule graphs (data/graphs.batch_molecules)",
        ),
    }
