"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base]:
24L d1024 16H (GQA kv=8) vocab 49155; MoE 32 experts top-8, d_ff=512."""
import jax.numpy as jnp

from repro.configs import base
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

ARCH_ID = "granite-moe-1b-a400m"

CONFIG = TransformerConfig(
    name=ARCH_ID,
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    activation="swiglu",
    tie_embeddings=True,
    moe=MoEConfig(num_experts=32, top_k=8, d_ff=512, num_shared=0),
    rope_theta=10000.0,
)


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=64,
        vocab_size=512,
        activation="swiglu",
        tie_embeddings=True,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff=32, num_shared=0,
                      capacity_factor=4.0),  # dropless at smoke scale
        dtype=jnp.float32,
        attn_chunk=8,
    )


def cells():
    return base.lm_cells(ARCH_ID, CONFIG)
