"""sasrec [arXiv:1808.09781]: embed_dim 50, 2 blocks, 1 head, seq_len 50,
self-attentive sequential recommendation over a 1M-item catalog.

Retrieval scoring (user state x item embedding) is a latent dot product —
the paper's pruning applies there (DESIGN.md §4, "partial")."""
import functools

import jax
import jax.numpy as jnp

from repro.configs import base
from repro.models import recsys

ARCH_ID = "sasrec"

# n_items + 1 (padding row) = 2^20 keeps the catalog table row-shardable
# over the full 512-device grid.
CONFIG = recsys.SASRecConfig(
    name=ARCH_ID, n_items=1_048_575, embed_dim=50, n_blocks=2, n_heads=1,
    seq_len=50,
)
PRUNE_T = 0.002


def smoke_config() -> recsys.SASRecConfig:
    return recsys.SASRecConfig(
        name=ARCH_ID + "-smoke", n_items=500, embed_dim=16, n_blocks=2,
        n_heads=1, seq_len=12,
    )


def _init(rng):
    return recsys.init_sasrec_params(rng, CONFIG)


def cells():
    def train():
        specs = {
            "seq": jax.ShapeDtypeStruct((65536, CONFIG.seq_len), jnp.int32),
            "pos": jax.ShapeDtypeStruct((65536, CONFIG.seq_len), jnp.int32),
            "neg": jax.ShapeDtypeStruct((65536, CONFIG.seq_len), jnp.int32),
        }
        return base.recsys_train_cell(
            ARCH_ID,
            "train_batch",
            init_fn=_init,
            loss_fn=functools.partial(recsys.sasrec_loss, cfg=CONFIG),
            batch_specs=specs,
        )

    def serve(shape_id, batch):
        def forward(params, b):
            h = recsys.sasrec_encode(params, b["seq"], CONFIG)[:, -1]
            return base.streaming_topk_scores(h, params["item_embed"], k=100)

        specs = {"seq": jax.ShapeDtypeStruct((batch, CONFIG.seq_len), jnp.int32)}
        return base.recsys_serve_cell(
            ARCH_ID, shape_id, init_fn=_init, forward_fn=forward,
            batch_specs=specs,
            note="catalog-scale top-100 via chunked streaming top-k merge",
        )

    def retrieval():
        def forward(params, b):
            return recsys.sasrec_retrieval(
                params, b["seq"], CONFIG, PRUNE_T, use_kernel=False,
                cand_ids=b["cand_ids"],
            )

        specs = {
            "seq": jax.ShapeDtypeStruct((1, CONFIG.seq_len), jnp.int32),
            "cand_ids": jax.ShapeDtypeStruct((1_000_000,), jnp.int32),
        }
        return base.recsys_serve_cell(
            ARCH_ID, "retrieval_cand", init_fn=_init, forward_fn=forward,
            batch_specs=specs, kind="retrieval",
            note="pruned latent scoring over 1M candidates",
        )

    return {
        "train_batch": train,
        "serve_p99": lambda: serve("serve_p99", 512),
        "serve_bulk": lambda: serve("serve_bulk", 262144),
        "retrieval_cand": retrieval,
    }
