"""bst [arXiv:1905.06874]: Behavior Sequence Transformer (Alibaba) —
embed_dim 32, 20-item history + target, 1 block x 8 heads, MLP 1024-512-256.
BST is a *ranking* model: retrieval_cand ranks 1M candidates through the
full transformer+MLP (the honest serving cost)."""
import functools

import jax
import jax.numpy as jnp

from repro.configs import base
from repro.models import recsys

ARCH_ID = "bst"

# n_items + 1 (padding row) = 2^20: catalog table row-shardable over 512 devs.
CONFIG = recsys.BSTConfig(
    name=ARCH_ID, n_items=1_048_575, embed_dim=32, seq_len=20, n_blocks=1,
    n_heads=8, mlp_dims=(1024, 512, 256), n_profile=16,
)


def smoke_config() -> recsys.BSTConfig:
    return recsys.BSTConfig(
        name=ARCH_ID + "-smoke", n_items=500, embed_dim=16, seq_len=8,
        n_blocks=1, n_heads=4, mlp_dims=(64, 32), n_profile=4,
    )


def _init(rng):
    return recsys.init_bst_params(rng, CONFIG)


def _batch_specs(batch: int):
    return {
        "hist": jax.ShapeDtypeStruct((batch, CONFIG.seq_len), jnp.int32),
        "target": jax.ShapeDtypeStruct((batch,), jnp.int32),
        "profile": jax.ShapeDtypeStruct((batch, CONFIG.n_profile), jnp.float32),
        "label": jax.ShapeDtypeStruct((batch,), jnp.float32),
    }


def cells():
    def train():
        return base.recsys_train_cell(
            ARCH_ID,
            "train_batch",
            init_fn=_init,
            loss_fn=functools.partial(recsys.bst_loss, cfg=CONFIG),
            batch_specs=_batch_specs(65536),
        )

    def serve(shape_id, batch):
        def forward(params, b):
            return recsys.bst_forward(
                params, b["hist"], b["target"], b["profile"], CONFIG
            )

        return base.recsys_serve_cell(
            ARCH_ID, shape_id, init_fn=_init, forward_fn=forward,
            batch_specs=_batch_specs(batch),
        )

    def retrieval():
        def forward(params, b):
            c = b["cand_ids"].shape[0]
            hist = jnp.broadcast_to(b["hist"], (c, CONFIG.seq_len))
            profile = jnp.broadcast_to(b["profile"], (c, CONFIG.n_profile))
            return recsys.bst_forward(params, hist, b["cand_ids"], profile, CONFIG)

        specs = {
            "hist": jax.ShapeDtypeStruct((1, CONFIG.seq_len), jnp.int32),
            "profile": jax.ShapeDtypeStruct((1, CONFIG.n_profile), jnp.float32),
            "cand_ids": jax.ShapeDtypeStruct((1_000_000,), jnp.int32),
        }
        return base.recsys_serve_cell(
            ARCH_ID, "retrieval_cand", init_fn=_init, forward_fn=forward,
            batch_specs=specs, kind="retrieval",
            note="full-model ranking of 1M candidates (BST is a ranker)",
        )

    return {
        "train_batch": train,
        "serve_p99": lambda: serve("serve_p99", 512),
        "serve_bulk": lambda: serve("serve_bulk", 262144),
        "retrieval_cand": retrieval,
    }
