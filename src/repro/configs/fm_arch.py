"""fm [Rendle ICDM'10]: 39 sparse fields, embed_dim 10, pairwise FM
interaction via the O(nk) sum-square trick.

This is the arch where the paper's technique is first-class: FM *is*
generalized MF, and every cell runs the dynamic-pruning path (threshold 0.02
on the factor table; rate-0 / threshold-0 recovers dense numerics exactly).
"""
import functools

import jax
import jax.numpy as jnp

from repro.configs import base
from repro.models import recsys

ARCH_ID = "fm"

# vocab 2^20 per field: the nearest device-grid-divisible size to the
# nominal 1M rows (tables row-shard over all 512 devices).
CONFIG = recsys.FMConfig(name=ARCH_ID, n_fields=39, embed_dim=10,
                         vocab_per_field=1_048_576)
PRUNE_T = 0.02


def smoke_config() -> recsys.FMConfig:
    return recsys.FMConfig(name=ARCH_ID + "-smoke", n_fields=8, embed_dim=10,
                           vocab_per_field=100)


def _init(rng):
    return recsys.init_fm_params(rng, CONFIG)


def _batch_specs(batch: int):
    return {
        "ids": jax.ShapeDtypeStruct((batch, CONFIG.n_fields), jnp.int32),
        "label": jax.ShapeDtypeStruct((batch,), jnp.float32),
    }


def cells():
    def train():
        return base.recsys_train_cell(
            ARCH_ID,
            "train_batch",
            init_fn=_init,
            loss_fn=functools.partial(recsys.fm_loss, cfg=CONFIG, t_v=PRUNE_T),
            batch_specs=_batch_specs(65536),
            note="pruned FM interaction (paper technique, first-class)",
        )

    def serve(shape_id, batch):
        def forward(params, b):
            return recsys.fm_forward(params, b["ids"], CONFIG, PRUNE_T)

        return base.recsys_serve_cell(
            ARCH_ID,
            shape_id,
            init_fn=_init,
            forward_fn=forward,
            batch_specs=_batch_specs(batch),
        )

    def retrieval():
        def forward(params, b):
            return recsys.fm_retrieval(
                params, b["user_ids"], b["cand_ids"], CONFIG, PRUNE_T,
                use_kernel=False,  # SPMD path; Pallas kernel used on-device
            )

        specs = {
            "user_ids": jax.ShapeDtypeStruct((1, CONFIG.n_fields - 1), jnp.int32),
            "cand_ids": jax.ShapeDtypeStruct((1_000_000,), jnp.int32),
        }
        return base.recsys_serve_cell(
            ARCH_ID,
            "retrieval_cand",
            init_fn=_init,
            forward_fn=forward,
            batch_specs=specs,
            kind="retrieval",
            note="FM decomposition: candidate scoring = one (B,k)x(C,k) pruned matmul",
        )

    return {
        "train_batch": train,
        "serve_p99": lambda: serve("serve_p99", 512),
        "serve_bulk": lambda: serve("serve_bulk", 262144),
        "retrieval_cand": retrieval,
    }
