"""dlrm-mlperf [arXiv:1906.00091]: MLPerf DLRM (Criteo 1TB) — 13 dense /
26 sparse fields, embed_dim 128, bot MLP 13-512-256-128, top MLP
1024-1024-512-256-1, dot interaction.  The dot-interaction block runs the
paper's pruned-factor path (embeddings masked by effective rank)."""
import functools

import jax
import jax.numpy as jnp

from repro.configs import base
from repro.models import recsys

ARCH_ID = "dlrm-mlperf"


def _pad512(v: int) -> int:
    """Round table rows up to a 512 multiple so every table row-shards over
    the full device grid (hash spaces are arbitrary; MLPerf itself caps them)."""
    return v + (-v) % 512


CONFIG = recsys.DLRMConfig(
    name=ARCH_ID,
    vocab_sizes=tuple(
        _pad512(v) if v >= 8192 else v for v in recsys.MLPERF_CRITEO_VOCABS
    ),
)
PRUNE_T = 0.002  # tables init at vocab^-0.5 — thresholds live on that scale


def smoke_config() -> recsys.DLRMConfig:
    return recsys.DLRMConfig(
        name=ARCH_ID + "-smoke",
        n_dense=5,
        embed_dim=16,
        vocab_sizes=(50, 60, 70),
        bot_mlp=(32, 16),
        top_mlp=(32, 16, 1),
    )


def _init(rng):
    return recsys.init_dlrm_params(rng, CONFIG)


def _batch_specs(batch: int):
    return {
        "dense": jax.ShapeDtypeStruct((batch, CONFIG.n_dense), jnp.float32),
        "sparse": jax.ShapeDtypeStruct((batch, CONFIG.n_sparse), jnp.int32),
        "label": jax.ShapeDtypeStruct((batch,), jnp.float32),
    }


def cells():
    def train():
        return base.recsys_train_cell(
            ARCH_ID,
            "train_batch",
            init_fn=_init,
            loss_fn=functools.partial(recsys.dlrm_loss, cfg=CONFIG, t_v=PRUNE_T),
            batch_specs=_batch_specs(65536),
            note="MLPerf DLRM; embeddings row-sharded over the full device grid",
        )

    def serve(shape_id, batch):
        def forward(params, b):
            return recsys.dlrm_forward(params, b["dense"], b["sparse"], CONFIG, PRUNE_T)

        return base.recsys_serve_cell(
            ARCH_ID, shape_id, init_fn=_init, forward_fn=forward,
            batch_specs=_batch_specs(batch),
        )

    def retrieval():
        def forward(params, b):
            return recsys.dlrm_retrieval(
                params, b["dense"], b["sparse"], b["cand_ids"], CONFIG, PRUNE_T
            )

        specs = {
            "dense": jax.ShapeDtypeStruct((1, CONFIG.n_dense), jnp.float32),
            "sparse": jax.ShapeDtypeStruct((1, CONFIG.n_sparse), jnp.int32),
            "cand_ids": jax.ShapeDtypeStruct((1_000_000,), jnp.int32),
        }
        return base.recsys_serve_cell(
            ARCH_ID,
            "retrieval_cand",
            init_fn=_init,
            forward_fn=forward,
            batch_specs=specs,
            kind="retrieval",
            note="rank 1M candidates through the full interaction+top-MLP",
        )

    return {
        "train_batch": train,
        "serve_p99": lambda: serve("serve_p99", 512),
        "serve_bulk": lambda: serve("serve_bulk", 262144),
        "retrieval_cand": retrieval,
    }
