"""Cell machinery: an (architecture x input-shape) cell bundles the step
function, abstract inputs (ShapeDtypeStructs — never allocated), and the
sharding assignment for a given mesh.  ``launch/dryrun.py`` lowers and
compiles every cell; smoke tests run reduced clones of the same builders.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shd
from repro.distributed.collectives import microbatch_grads
from repro.models import gnn as gnn_lib
from repro.models import recsys as rec_lib
from repro.models import transformer as tfm
from repro.optim.optimizers import Adam, Sgd

Pytree = Any


@dataclasses.dataclass
class CellSpec:
    arch: str
    shape_id: str
    kind: str  # train | prefill | decode | serve | retrieval
    step_fn: Callable
    abstract_args: Tuple
    in_shardings: Callable[[Mesh], Tuple]
    donate_argnums: Tuple[int, ...] = ()
    note: str = ""

    @property
    def cell_id(self) -> str:
        return f"{self.arch}::{self.shape_id}"


def abstract_like(fn, *args, **kwargs):
    return jax.eval_shape(fn, *args, **kwargs)


def _adam_shardings(param_sh):
    return {
        "m": param_sh,
        "v": param_sh,
        "t": None,  # filled by caller with replicated sharding
    }


# ---------------------------------------------------------------------------
# LM transformer cells
# ---------------------------------------------------------------------------


def lm_train_cell(
    arch: str,
    shape_id: str,
    cfg: tfm.TransformerConfig,
    *,
    global_batch: int,
    seq_len: int,
    n_micro: int = 1,
    lr: float = 3e-4,
) -> CellSpec:
    optimizer = Adam(lr=lr)

    def loss_fn(params, batch):
        return tfm.lm_loss(params, batch, cfg)

    def step(params, opt_state, batch):
        loss, grads = microbatch_grads(loss_fn, params, batch, n_micro)
        params, opt_state = optimizer.apply(params, opt_state, grads)
        return params, opt_state, loss

    rng = jax.random.PRNGKey(0)
    a_params = abstract_like(functools.partial(tfm.init_params, cfg=cfg), rng)
    a_opt = abstract_like(optimizer.init, a_params)
    a_batch = {
        "tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
    }

    def in_shardings(mesh: Mesh):
        p_sh = shd.transformer_param_shardings(a_params, mesh)
        o_sh = {
            "m": p_sh,
            "v": jax.tree_util.tree_map(lambda s: s, p_sh),
            "t": shd.replicated(mesh),
        }
        b_sh = shd.lm_batch_shardings(mesh)
        return (p_sh, o_sh, b_sh)

    return CellSpec(
        arch=arch,
        shape_id=shape_id,
        kind="train",
        step_fn=step,
        abstract_args=(a_params, a_opt, a_batch),
        in_shardings=in_shardings,
        donate_argnums=(0, 1),
    )


def lm_prefill_cell(
    arch: str,
    shape_id: str,
    cfg: tfm.TransformerConfig,
    *,
    global_batch: int,
    seq_len: int,
) -> CellSpec:
    def step(params, tokens):
        return tfm.prefill(params, tokens, cfg)

    rng = jax.random.PRNGKey(0)
    a_params = abstract_like(functools.partial(tfm.init_params, cfg=cfg), rng)
    a_tokens = jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32)

    def in_shardings(mesh: Mesh):
        return (
            shd.transformer_param_shardings(a_params, mesh),
            shd.ns(mesh, shd.data_axes(mesh), None),
        )

    return CellSpec(
        arch=arch,
        shape_id=shape_id,
        kind="prefill",
        step_fn=step,
        abstract_args=(a_params, a_tokens),
        in_shardings=in_shardings,
    )


def lm_decode_cell(
    arch: str,
    shape_id: str,
    cfg: tfm.TransformerConfig,
    *,
    global_batch: int,
    kv_len: int,
    shard_seq: bool = False,
    note: str = "",
) -> CellSpec:
    """One-token decode against a kv_len cache.  ``shard_seq`` shards the KV
    sequence axis instead of batch (SP decode — the batch=1 long-context
    cells)."""

    def step(params, state, tokens):
        return tfm.decode_step(params, tokens, state, cfg)

    rng = jax.random.PRNGKey(0)
    a_params = abstract_like(functools.partial(tfm.init_params, cfg=cfg), rng)
    a_state = abstract_like(
        functools.partial(
            tfm.init_decode_state, cfg, global_batch, kv_len, length=kv_len - 1
        )
    )
    a_tokens = jax.ShapeDtypeStruct((global_batch, 1), jnp.int32)

    def in_shardings(mesh: Mesh):
        state_sh = shd.tree_shardings(
            a_state, shd.decode_state_spec_fn(mesh, shard_seq=shard_seq), mesh
        )
        return (
            shd.transformer_param_shardings(a_params, mesh),
            state_sh,
            shd.ns(mesh, shd.data_axes(mesh), None) if not shard_seq
            else shd.ns(mesh, None, None),
        )

    return CellSpec(
        arch=arch,
        shape_id=shape_id,
        kind="decode",
        step_fn=step,
        abstract_args=(a_params, a_state, a_tokens),
        in_shardings=in_shardings,
        donate_argnums=(1,),
        note=note,
    )


LM_SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


def lm_cells(arch: str, cfg: tfm.TransformerConfig) -> Dict[str, Callable[[], CellSpec]]:
    return {
        "train_4k": lambda: lm_train_cell(
            arch, "train_4k", cfg, global_batch=256, seq_len=4096
        ),
        "prefill_32k": lambda: lm_prefill_cell(
            arch, "prefill_32k", cfg, global_batch=32, seq_len=32768
        ),
        "decode_32k": lambda: lm_decode_cell(
            arch, "decode_32k", cfg, global_batch=128, kv_len=32768
        ),
        "long_500k": lambda: lm_decode_cell(
            arch,
            "long_500k",
            cfg,
            global_batch=1,
            kv_len=524288,
            shard_seq=True,
            note=(
                "long-context decode is O(L) (one query vs cached KV) — "
                "runnable with full attention; KV sequence axis sharded (SP)."
            ),
        ),
    }


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------


def gnn_train_cell(
    arch: str,
    shape_id: str,
    cfg: gnn_lib.GATConfig,
    *,
    num_nodes: int,
    num_edges: int,
    with_edge_mask: bool = False,
    lr: float = 5e-3,
    note: str = "",
    pad_multiple: int = 512,
) -> CellSpec:
    # Pad node/edge counts to the device-grid multiple so both stay shardable
    # (padded nodes carry label -1, padded edges carry mask 0 — the data
    # pipeline produces exactly this layout).
    if num_nodes % pad_multiple or num_edges % pad_multiple:
        num_nodes += (-num_nodes) % pad_multiple
        num_edges += (-num_edges) % pad_multiple
        with_edge_mask = True
    optimizer = Adam(lr=lr)

    def loss_fn(params, batch):
        return gnn_lib.loss_fn(params, batch, cfg)

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = optimizer.apply(params, opt_state, grads)
        return params, opt_state, loss

    rng = jax.random.PRNGKey(0)
    a_params = abstract_like(functools.partial(gnn_lib.init_params, cfg=cfg), rng)
    a_opt = abstract_like(optimizer.init, a_params)
    a_batch = {
        "features": jax.ShapeDtypeStruct((num_nodes, cfg.d_feat), jnp.float32),
        "edges": jax.ShapeDtypeStruct((num_edges, 2), jnp.int32),
        "labels": jax.ShapeDtypeStruct((num_nodes,), jnp.int32),
    }
    if with_edge_mask:
        a_batch["edge_mask"] = jax.ShapeDtypeStruct((num_edges,), jnp.float32)

    def in_shardings(mesh: Mesh):
        p_sh = shd.tree_shardings(a_params, shd.gnn_spec_fn(mesh), mesh)
        o_sh = {
            "m": p_sh,
            "v": jax.tree_util.tree_map(lambda s: s, p_sh),
            "t": shd.replicated(mesh),
        }
        b_all = shd.gnn_batch_shardings(mesh)
        b_sh = {key: b_all[key] for key in a_batch}
        return (p_sh, o_sh, b_sh)

    return CellSpec(
        arch=arch,
        shape_id=shape_id,
        kind="train",
        step_fn=step,
        abstract_args=(a_params, a_opt, a_batch),
        in_shardings=in_shardings,
        donate_argnums=(0, 1),
        note=note,
    )


# ---------------------------------------------------------------------------
# RecSys cells (shared step builders)
# ---------------------------------------------------------------------------

RECSYS_SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_candidates=1_000_000),
}


def recsys_train_cell(
    arch: str,
    shape_id: str,
    *,
    init_fn,
    loss_fn,
    batch_specs: Dict[str, jax.ShapeDtypeStruct],
    lr: float = 1e-2,
    note: str = "",
) -> CellSpec:
    optimizer = Sgd(lr=lr)  # MLPerf DLRM trains embeddings with plain SGD

    def step(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, _ = optimizer.apply(params, {}, grads)
        return params, loss

    a_params = abstract_like(init_fn, jax.random.PRNGKey(0))

    def in_shardings(mesh: Mesh):
        p_sh = shd.tree_shardings(a_params, shd.recsys_spec_fn(mesh), mesh)
        b_sh = shd.recsys_batch_shardings(mesh, batch_specs)
        return (p_sh, b_sh)

    return CellSpec(
        arch=arch,
        shape_id=shape_id,
        kind="train",
        step_fn=step,
        abstract_args=(a_params, batch_specs),
        in_shardings=in_shardings,
        donate_argnums=(0,),
        note=note,
    )


def recsys_serve_cell(
    arch: str,
    shape_id: str,
    *,
    init_fn,
    forward_fn,
    batch_specs: Dict[str, jax.ShapeDtypeStruct],
    kind: str = "serve",
    note: str = "",
) -> CellSpec:
    a_params = abstract_like(init_fn, jax.random.PRNGKey(0))

    def step(params, batch):
        return forward_fn(params, batch)

    def in_shardings(mesh: Mesh):
        p_sh = shd.tree_shardings(a_params, shd.recsys_spec_fn(mesh), mesh)
        b_sh = shd.recsys_batch_shardings(mesh, batch_specs)
        return (p_sh, b_sh)

    return CellSpec(
        arch=arch,
        shape_id=shape_id,
        kind=kind,
        step_fn=step,
        abstract_args=(a_params, batch_specs),
        in_shardings=in_shardings,
        note=note,
    )


def streaming_topk_scores(
    h: jax.Array,       # (B, d) user states
    table: jax.Array,   # (V, d) item embeddings
    *,
    k: int = 100,
    chunk: int = 65536,
) -> Tuple[jax.Array, jax.Array]:
    """Catalog-scale retrieval: score against the item table in chunks with a
    running top-k merge, so peak memory is (B, chunk) instead of (B, V)."""
    v = table.shape[0]
    n_chunks = max(v // chunk, 1)

    # Unrolled python loop (not lax.scan) so cost_analysis counts every
    # chunk's matmul — while bodies are costed once per program, not per trip.
    best_s = jnp.full((h.shape[0], k), -jnp.inf, h.dtype)
    best_i = jnp.zeros((h.shape[0], k), jnp.int32)
    for idx in range(n_chunks):
        tab = jax.lax.dynamic_slice_in_dim(table, idx * chunk, chunk, axis=0)
        scores = jnp.einsum("bd,cd->bc", h, tab)
        ids = idx * chunk + jnp.arange(chunk, dtype=jnp.int32)
        cat_s = jnp.concatenate([best_s, scores], axis=1)
        cat_i = jnp.concatenate(
            [best_i, jnp.broadcast_to(ids[None], scores.shape).astype(jnp.int32)],
            axis=1,
        )
        best_s, pos = jax.lax.top_k(cat_s, k)
        best_i = jnp.take_along_axis(cat_i, pos, axis=1)
    return best_s, best_i
