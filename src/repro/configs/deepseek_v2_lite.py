"""deepseek-v2-lite-16b [arXiv:2405.04434]: 27L d2048 16H, MLA
(kv_lora=512, nope=128, rope=64, v=128), vocab 102400; MoE: 64 routed
top-6 + 2 shared experts, d_ff=1408 per expert; layer 0 is dense
(d_ff=10944).

Assignment-line discrepancy (DESIGN.md §4): the line says "64e top-6" and the
note "2 shared+160 routed"; 160 routed is DeepSeek-V2 (236B).  V2-Lite is
64 routed + 2 shared top-6 — implemented as such.
"""
import jax.numpy as jnp

from repro.configs import base
from repro.models.attention import MLAConfig
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

ARCH_ID = "deepseek-v2-lite-16b"

CONFIG = TransformerConfig(
    name=ARCH_ID,
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    activation="swiglu",
    tie_embeddings=False,
    mla=MLAConfig(
        kv_lora_rank=512, qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128
    ),
    moe=MoEConfig(num_experts=64, top_k=6, d_ff=1408, num_shared=2),
    first_dense_layers=1,
    first_dense_ff=10944,
    rope_theta=10000.0,
)


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=64,
        vocab_size=512,
        activation="swiglu",
        tie_embeddings=False,
        mla=MLAConfig(
            kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16
        ),
        moe=MoEConfig(num_experts=8, top_k=2, d_ff=32, num_shared=2,
                      capacity_factor=4.0),  # dropless at smoke scale
        first_dense_layers=1,
        first_dense_ff=128,
        dtype=jnp.float32,
        attn_chunk=8,
    )


def cells():
    return base.lm_cells(ARCH_ID, CONFIG)
