"""repro: dynamic-pruning matrix factorization (DP-MF) framework in JAX."""
__version__ = "0.1.0"
