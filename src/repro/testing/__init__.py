"""Test-only instrumentation: the deterministic fault-injection harness.

Nothing under ``repro.testing`` runs on the hot path in production: every
seam guards on a single module-attribute ``None`` check
(``faults._PLAN is None``) and does zero further work when no plan is
installed.
"""
from repro.testing.faults import (  # noqa: F401
    FaultAction,
    FaultError,
    FaultPlan,
    corrupt_message,
    fire,
    install,
    installed,
    uninstall,
)
