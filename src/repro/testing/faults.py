"""Deterministic chaos harness: seeded fault plans behind test seams.

A :class:`FaultPlan` is an explicit schedule of :class:`FaultAction`\\ s
("kill replica r1 at its 40th submit", "corrupt the 3rd delivery to r0",
"fail the next checkpoint fsync").  Production code carries *seams* —
named call sites that ask the harness whether anything fires now:

    from repro.testing import faults
    ...
    if faults._PLAN is not None:          # one attribute read when off
        for act in faults.fire("bus.deliver", replica_id):
            ...

The guard is the whole production cost: with no plan installed the seam
is a single module-attribute ``None`` check, no function call, no lock.
Tests install a plan (``faults.install`` / the ``faults.installed``
context manager) and the same seams start firing deterministically —
every action triggers at an exact per-``(site, target)`` event count, so
the same plan replays the same failure schedule every run, and
:meth:`FaultPlan.from_seed` derives a whole adversarial schedule from one
integer seed.

Seam names used across the repo (grep for ``faults.fire``):

* ``"replica.submit"`` (target = replica id) — ops: ``kill``.
* ``"bus.deliver"``    (target = replica id) — ops: ``drop``, ``dup``,
  ``corrupt``, ``delay``.
* ``"checkpoint.fsync"`` — ops: ``error`` (the save aborts pre-publish).
* ``"trainer.slab"``   — ops: ``error`` (a retryable step failure).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, List, Optional, Sequence, Tuple


class FaultError(RuntimeError):
    """An injected failure — raised by seams executing an ``error`` op."""


@dataclasses.dataclass(frozen=True)
class FaultAction:
    """One scheduled fault.

    Fires when the seam named ``site`` sees its ``at``-th event (0-based,
    counted per ``(site, target)``) for ``target`` (``""`` matches every
    target at the site).  ``op`` is interpreted by the seam; ``arg``
    carries an op parameter (e.g. delay seconds).  Each action fires at
    most once — a plan wanting N kills schedules N actions.
    """

    site: str
    op: str
    at: int
    target: str = ""
    arg: float = 0.0


class FaultPlan:
    """A deterministic schedule of fault actions plus its firing log.

    ``fire(site, target)`` bumps the per-``(site, target)`` event counter
    and returns the actions whose ``at`` matches the pre-bump count —
    callers execute the returned ops.  ``fired`` records every trigger as
    ``(site, target, op, count)`` so tests can assert the schedule
    actually ran.  Thread-safe: seams fire from scheduler, reader, and
    supervisor threads concurrently.
    """

    def __init__(self, actions: Sequence[FaultAction] = ()):
        self._actions: List[FaultAction] = list(actions)
        self._spent: set = set()           # indices already fired
        self._counts: Dict[Tuple[str, str], int] = {}
        self._lock = threading.Lock()
        self.fired: List[Tuple[str, str, str, int]] = []

    @classmethod
    def from_seed(
        cls,
        seed: int,
        *,
        sites: Sequence[Tuple[str, Sequence[str], Sequence[str]]],
        n_actions: int = 8,
        horizon: int = 32,
    ) -> "FaultPlan":
        """Derive an adversarial schedule from one integer seed.

        ``sites`` is ``[(site, targets, ops), ...]``; ``n_actions`` faults
        are drawn uniformly over (site row, target, op, at < horizon).
        Same seed ⇒ same schedule, regardless of interleaving at run time
        (numpy's PCG64 stream is platform-stable).
        """
        import numpy as np

        rng = np.random.default_rng(seed)
        actions = []
        for _ in range(int(n_actions)):
            site, targets, ops = sites[int(rng.integers(len(sites)))]
            target = str(targets[int(rng.integers(len(targets)))]) if targets else ""
            op = str(ops[int(rng.integers(len(ops)))])
            actions.append(FaultAction(
                site=site, op=op, at=int(rng.integers(horizon)), target=target,
            ))
        return cls(actions)

    def fire(self, site: str, target: str = "") -> List[FaultAction]:
        """One event at ``(site, target)``: returns the actions firing now."""
        with self._lock:
            key = (site, target)
            count = self._counts.get(key, 0)
            self._counts[key] = count + 1
            hits = []
            for i, act in enumerate(self._actions):
                if i in self._spent or act.site != site or act.at != count:
                    continue
                if act.target and act.target != target:
                    continue
                self._spent.add(i)
                hits.append(act)
                self.fired.append((site, target, act.op, count))
            return hits

    @property
    def pending(self) -> int:
        """Actions scheduled but not yet fired."""
        return len(self._actions) - len(self._spent)


# The installed plan.  ``None`` in production — seams guard on exactly this
# attribute so the disabled cost is one module-attribute read.
_PLAN: Optional[FaultPlan] = None


def install(plan: FaultPlan) -> FaultPlan:
    """Arm the harness: subsequent seam events consult ``plan``."""
    global _PLAN
    _PLAN = plan
    return plan


def uninstall() -> None:
    """Disarm the harness (seams return to the production no-op)."""
    global _PLAN
    _PLAN = None


@contextlib.contextmanager
def installed(plan: FaultPlan):
    """Scoped install — the test-suite idiom (always disarms on exit)."""
    install(plan)
    try:
        yield plan
    finally:
        uninstall()


def fire(site: str, target: str = "") -> Sequence[FaultAction]:
    """Seam entry point.  Callers should pre-guard with
    ``faults._PLAN is not None`` so production pays only the attribute
    read; this function re-checks for safety."""
    plan = _PLAN
    if plan is None:
        return ()
    return plan.fire(site, target)


def corrupt_message(msg):
    """Bit-flip one payload array of a DeltaMessage *without* fixing its
    checksum — what a corrupted wire delivery looks like to the sink."""
    import dataclasses as dc

    import numpy as np

    from repro.distributed.compression import CompressedArray

    tree = dict(msg.tree)
    # flip a byte in the largest payload so the CRC check must catch it
    key = max(
        tree,
        key=lambda k: tree[k].nbytes if isinstance(tree[k], CompressedArray)
        else int(np.asarray(tree[k]).nbytes),
    )
    val = tree[key]
    if isinstance(val, CompressedArray):
        blob = bytearray(val.data)
        blob[len(blob) // 2] ^= 0xFF
        tree[key] = CompressedArray(
            data=bytes(blob), shape=val.shape, dtype=val.dtype, codec=val.codec,
        )
    else:
        arr = np.array(val, copy=True)
        flat = arr.view(np.uint8).reshape(-1)
        flat[len(flat) // 2] ^= 0xFF
        tree[key] = arr
    return dc.replace(msg, tree=tree)


def delay_s(actions: Sequence[FaultAction]) -> float:
    """Total delay requested by ``delay`` ops in ``actions`` (seconds)."""
    return sum(a.arg for a in actions if a.op == "delay")
