"""Shared benchmark helpers: CSV emission + wall-clock timing."""
from __future__ import annotations

import time
from typing import Callable

import jax


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """The harness contract: ``name,us_per_call,derived`` CSV lines."""
    print(f"{name},{us_per_call:.3f},{derived}")


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time (microseconds) of a jax-producing callable."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        start = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - start)
    times.sort()
    return times[len(times) // 2] * 1e6
