"""Shared benchmark helpers: CSV emission + wall-clock timing + JSON reports.

Every ``emit`` line is also recorded in-process; suites call
:func:`write_json` at the end of their ``run`` to drop a machine-readable
``BENCH_<suite>.json``, so the perf trajectory (throughput, speedup, p99)
is trackable across PRs without scraping stdout.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Optional

import jax

_RECORDS: List[Dict] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """The harness contract: ``name,us_per_call,derived`` CSV lines."""
    print(f"{name},{us_per_call:.3f},{derived}")
    _RECORDS.append(
        {"name": name, "us_per_call": float(us_per_call), "derived": derived}
    )


def reset_records() -> None:
    _RECORDS.clear()


def records() -> List[Dict]:
    return list(_RECORDS)


def _proc_status_kb(field: str, path: str = "/proc/self/status") -> float:
    """Read one kB-valued field from a /proc status-style file (0.0 when the
    platform doesn't expose it — peak-RSS stamping is best-effort)."""
    try:
        with open(path) as f:
            for line in f:
                if line.startswith(field + ":"):
                    return float(line.split()[1])
    except OSError:
        pass
    return 0.0


def peak_rss_mb() -> float:
    """Lifetime peak resident set (VmHWM) of this process, in MiB."""
    return _proc_status_kb("VmHWM") / 1024.0


def anonymous_rss_mb() -> float:
    """Current *anonymous* resident set in MiB — the part of RSS that is not
    reclaimable page cache.  File-backed mmap pages (the ratings store's
    shards) count toward plain RSS but the kernel drops them under pressure,
    so bounded-memory assertions must look at this number instead."""
    return _proc_status_kb("Anonymous", "/proc/self/smaps_rollup") / 1024.0


def write_json(
    suite: str,
    summary: Optional[Dict] = None,
    *,
    directory: Optional[str] = None,
) -> str:
    """Write ``BENCH_<suite>.json``: every emit record since the last reset
    plus a suite-level ``summary`` dict of headline numbers.  The output
    directory defaults to ``$BENCH_JSON_DIR`` or the CWD.  Every report is
    stamped with the process's peak RSS (``peak_rss_mb``) so the perf
    trajectory tracks memory alongside time.  Returns the path."""
    directory = directory or os.environ.get("BENCH_JSON_DIR") or "."
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"BENCH_{suite}.json")
    payload = {
        "suite": suite,
        "unix_time": int(time.time()),
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "peak_rss_mb": peak_rss_mb(),
        "summary": summary or {},
        "records": records(),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {path}")
    return path


_SCHEMA = {
    "suite": str,
    "unix_time": int,
    "backend": str,
    "device_count": int,
    "peak_rss_mb": (int, float),
    "summary": dict,
    "records": list,
}
_RECORD_SCHEMA = {"name": str, "us_per_call": (int, float), "derived": str}


def validate_report(path: str) -> Dict:
    """Schema-check one ``BENCH_<suite>.json`` report; returns the payload.

    Guards the machine-readable perf-trajectory contract: every report must
    carry the envelope fields and well-typed emit records, so downstream
    tooling (and the CI smoke job) notices a suite that silently stopped
    recording.  Raises ``ValueError`` with the first violation.
    """
    if not os.path.exists(path):
        raise ValueError(f"missing bench report {path}")
    with open(path) as f:
        payload = json.load(f)
    for key, typ in _SCHEMA.items():
        if key not in payload:
            raise ValueError(f"{path}: missing key {key!r}")
        if not isinstance(payload[key], typ):
            raise ValueError(
                f"{path}: {key!r} should be {typ}, got {type(payload[key])}"
            )
    suite = payload["suite"]
    if not path.endswith(f"BENCH_{suite}.json"):
        raise ValueError(f"{path}: suite field {suite!r} mismatches filename")
    if not payload["records"]:
        raise ValueError(f"{path}: empty records — suite emitted nothing")
    for i, rec in enumerate(payload["records"]):
        for key, typ in _RECORD_SCHEMA.items():
            if key not in rec or not isinstance(rec[key], typ):
                raise ValueError(
                    f"{path}: record {i} field {key!r} missing or mistyped: "
                    f"{rec!r}"
                )
    return payload


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time (microseconds) of a jax-producing callable."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        start = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - start)
    times.sort()
    return times[len(times) // 2] * 1e6
