"""Serving engine vs. the dense score-everything-then-argsort path.

    PYTHONPATH=src python -m benchmarks.bench_serving [--full]

Four claims, checked then timed:

1. **parity** — the engine's streaming top-k (and the Pallas kernel in
   interpret mode at a small shape) returns *identical* (indices, scores) to
   the dense oracle (pruned scores -> stable argsort);
2. **memory** — the dense path materializes a (B, n) f32 score matrix per
   batch; the engine's peak live scoring buffer is (B, topk + block_n);
3. **speed** — wall-clock per request batch, dense vs. engine, CSV-emitted
   via the ``name,us_per_call,derived`` harness contract;
4. **concurrency** — single-user requests from 32 concurrent clients through
   the async queue (continuous batching) vs the same requests scored one at
   a time; byte-identical results, and throughput must be >= 2x sequential.
"""
from __future__ import annotations

import argparse
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, reset_records, time_fn, write_json
from repro.core import mf
from repro.core.ranks import effective_ranks
from repro.kernels import ops, ref
from repro.serving import RequestQueue, ServingEngine


def dense_oracle(params, users, t_p, t_q, topk):
    """The path this engine replaces: full (B, n) scores, host argsort."""
    scores = mf.predict_all_items(params, users, t_p, t_q, use_kernel=False)
    idx = jnp.argsort(-scores, axis=1)[:, :topk].astype(jnp.int32)
    return jnp.take_along_axis(scores, idx, axis=1), idx


def run(*, full: bool = False, smoke: bool = False) -> None:
    reset_records()
    if smoke:
        m, n, k = 1024, 8000, 32
    elif full:
        m, n, k = 20000, 200000, 64
    else:
        m, n, k = 4096, 40000, 48
    batch, topk, t = 256, 10, 0.05
    rng = np.random.default_rng(0)

    params = mf.init_params(jax.random.PRNGKey(0), m, n, k, variant="bias",
                            global_mean=3.5)
    users = jnp.asarray(rng.integers(0, m, batch), np.int32)
    engine = ServingEngine(params, t, t, use_kernel=False,
                           max_batch=batch)

    # ---- parity: engine == oracle, bit-for-bit on indices -----------------
    o_scores, o_idx = dense_oracle(params, users, t, t, topk)
    e_scores, e_idx = engine.topk(np.asarray(users), topk)
    assert np.array_equal(np.asarray(o_idx), e_idx), "engine != oracle items"
    np.testing.assert_allclose(np.asarray(o_scores), e_scores,
                               rtol=1e-5, atol=1e-5)
    print(f"# parity OK: engine == dense argsort oracle "
          f"({batch} users x {n} items, top-{topk})")

    # kernel (interpret mode) parity at a reduced shape — interpret mode is
    # pure-python slow, so keep it a correctness probe, not a timing run
    sm, sn = 64, 2048
    sp = params.p[:sm]
    sq = params.q[:sn]
    r_u, r_i = effective_ranks(sp, t), effective_ranks(sq, t)
    ks, ki = ops.pruned_topk(sp, sq, t, t, topk, use_kernel=True,
                             interpret=True)
    rs, ri_ = ref.pruned_topk_ref(sp, sq, r_u, r_i, topk)
    assert np.array_equal(np.asarray(ki), np.asarray(ri_)), "kernel != oracle"
    np.testing.assert_allclose(np.asarray(ks), np.asarray(rs),
                               rtol=1e-5, atol=1e-5)
    print("# parity OK: Pallas pruned-topk kernel (interpret) == oracle")

    # ---- memory -----------------------------------------------------------
    dense_bytes = batch * n * 4
    engine_bytes = batch * (topk + engine.block_n) * 4
    print(f"# scoring buffer: dense {dense_bytes / 1e6:.1f} MB per batch vs "
          f"engine {engine_bytes / 1e6:.3f} MB "
          f"({dense_bytes / engine_bytes:.0f}x smaller, catalog-independent)")

    # ---- speed ------------------------------------------------------------
    users_np = np.asarray(users)

    def run_dense():
        return dense_oracle(params, users, t, t, topk)[1]

    def run_engine():
        return jnp.asarray(engine.topk(users_np, topk)[1])

    us_dense = time_fn(run_dense, warmup=1, iters=5)
    us_engine = time_fn(run_engine, warmup=1, iters=5)
    emit(f"serve_dense_argsort_b{batch}_n{n}", us_dense,
         f"{batch / (us_dense / 1e6):.0f} req/s")
    emit(f"serve_engine_topk_b{batch}_n{n}", us_engine,
         f"{batch / (us_engine / 1e6):.0f} req/s")
    emit(f"serve_speedup_b{batch}_n{n}", us_dense / us_engine, "x dense")
    print(f"# engine speedup over dense argsort: "
          f"{us_dense / us_engine:.2f}x")

    # ---- throughput under concurrency (async queue vs sequential) ---------
    conc, n_req = 32, 256
    req_users = rng.integers(0, m, n_req)
    # warm every power-of-two bucket the queue's batches can land in, plus
    # the sequential path's bucket-1 program
    for b_ in (1, 2, 4, 8, 16, 32):
        engine.topk(users_np[:b_], topk)

    seq_results = {}
    start = time.perf_counter()
    for u in req_users:
        seq_results[int(u)] = engine.topk([int(u)], topk)
    t_seq = time.perf_counter() - start

    queue = RequestQueue(engine, linger_ms=1.0, max_pending=n_req)
    req_latencies = []

    def one_request(u):
        t0 = time.perf_counter()
        result = queue.submit(int(u), topk, timeout=120).result(timeout=120)
        req_latencies.append(time.perf_counter() - t0)
        return result

    with ThreadPoolExecutor(max_workers=conc) as pool:
        list(pool.map(one_request, req_users[:64]))  # warm the queue path
        start = time.perf_counter()
        q_results = list(pool.map(one_request, req_users))
        t_queue = time.perf_counter() - start
    queue.close()

    for u, (got_s, got_i) in zip(req_users, q_results):
        want_s, want_i = seq_results[int(u)]
        assert np.array_equal(got_s, want_s[0]), "queue != sequential scores"
        assert np.array_equal(got_i, want_i[0]), "queue != sequential items"
    print(f"# parity OK: queue-fed results byte-identical to sequential "
          f"({n_req} requests)")

    seq_rps = n_req / t_seq
    queue_rps = n_req / t_queue
    speedup = t_seq / t_queue
    emit(f"serve_sequential_1by1_n{n}", t_seq / n_req * 1e6,
         f"{seq_rps:.0f} req/s")
    emit(f"serve_queue_c{conc}_n{n}", t_queue / n_req * 1e6,
         f"{queue_rps:.0f} req/s")
    emit(f"serve_queue_speedup_c{conc}_n{n}", speedup, "x sequential")
    print(f"# async queue at concurrency {conc}: {queue_rps:.0f} req/s vs "
          f"{seq_rps:.0f} sequential ({speedup:.1f}x; "
          f"{queue.batches_served} launches, mean batch "
          f"{queue.requests_served / max(queue.batches_served, 1):.1f})")
    if not smoke:
        # at smoke's toy catalog the per-request work is too small for
        # batching to amortize the queue handoff; the gate is a perf
        # assertion, not a correctness one
        assert speedup >= 2.0, (
            f"continuous batching must be >= 2x sequential, got {speedup:.2f}x"
        )

    lat_ms = np.asarray(req_latencies[-n_req:]) * 1e3
    p50, p99 = np.percentile(lat_ms, [50, 99])
    write_json("serving", {
        "shape": {"users": m, "items": n, "k": k, "batch": batch,
                  "topk": topk},
        "engine_speedup_x_dense": us_dense / us_engine,
        "engine_req_per_s": batch / (us_engine / 1e6),
        "queue_req_per_s": queue_rps,
        "queue_speedup_x_sequential": speedup,
        "queue_latency_ms_p50": float(p50),
        "queue_latency_ms_p99": float(p99),
    })


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--full", action="store_true",
                        help="catalog-scale shape (slower)")
    args = parser.parse_args()
    run(full=args.full)


if __name__ == "__main__":
    main()
