"""Evaluation subsystem: pruned-vs-dense metric gap + eval throughput.

    PYTHONPATH=src python -m benchmarks.bench_eval [--full]

Three claims, checked then timed:

1. **metric plumbing is exact** — at thresholds 0 the engine's ranking
   metrics (HR@K/NDCG@K/recall@K through ``ServingEngine.topk``) equal the
   brute-force dense oracle's *exactly* (same users, same indices, same
   math), so any gap measured at trained thresholds is pruning, never
   plumbing (asserted);
2. **the pruning error band, in ranking terms** — relevance is defined as
   the *dense model's own* top-L items per user, so the dense oracle scores
   HR = NDCG = recall = 1.0 by construction and the pruned engine's
   shortfall IS the ranking distortion pruning introduces (the
   ranking-side analogue of the paper's P_MAE, Eq. 13, free of dataset
   artifacts);
3. **eval is cheap enough to run continuously** — users/s of the engine
   ranking eval and of the one-scan ``mf.eval_ranking_epoch_scan`` variant,
   and events/s of prequential test-then-learn scoring vs plain updates
   (the overhead of folding eval into the online path).

Emits the ``name,us_per_call,derived`` CSV contract and writes
``BENCH_eval.json`` (schema-validated by ``benchmarks/run.py --smoke``).
"""
from __future__ import annotations

import argparse
import time
import types

import jax
import numpy as np

from benchmarks.common import emit, reset_records, time_fn, write_json
from repro.core import mf, threshold
from repro.data import synthetic_ratings, train_test_split
from repro.eval import PrequentialEvaluator
from repro.eval import ranking as ranking_eval
from repro.online import OnlineUpdater, ReplaySource, iter_microbatches
from repro.serving import ServingEngine


def run(*, full: bool = False, smoke: bool = False) -> None:
    reset_records()
    if smoke:
        m, n, k, ratings = 400, 3000, 16, 6000
        topk, rate, stream_events = 10, 0.4, 512
    elif full:
        m, n, k, ratings = 20000, 100000, 64, 400000
        topk, rate, stream_events = 10, 0.4, 8192
    else:
        m, n, k, ratings = 2048, 20000, 48, 60000
        topk, rate, stream_events = 10, 0.4, 4096

    ds = synthetic_ratings(num_users=m, num_items=n, num_ratings=ratings,
                           seed=0)
    _, stream_ds = train_test_split(ds, 0.5, seed=1)
    params = mf.init_params(
        jax.random.PRNGKey(0), m, n, k, init_method="libmf"
    )
    t_p, t_q = threshold.thresholds_from_matrices(params.p, params.q, rate)

    # Relevance = each user's DENSE top-L: the dense oracle then scores a
    # perfect 1.0 on every metric, so the pruned engine's shortfall is
    # exactly the ranking distortion pruning introduces.
    rel_l = 5
    eval_users = np.arange(min(m, 2048), dtype=np.int32)
    rel_items = np.concatenate([
        ranking_eval.dense_topk(params, eval_users[lo : lo + 256], rel_l)[1]
        for lo in range(0, eval_users.size, 256)
    ])
    holdout = types.SimpleNamespace(
        user=np.repeat(eval_users, rel_l),
        item=rel_items.reshape(-1),
        rating=np.ones(eval_users.size * rel_l, np.float32),
    )
    # pack the relevance sets ONCE; every evaluate_* call below reuses them,
    # so the timed sections measure ranking, not holdout re-sorting
    relevance = ranking_eval.relevance_from_dataset(holdout)
    users = relevance[0]

    # ---- 1. parity: engine metrics == dense oracle at thresholds 0 ---------
    dense_engine = ServingEngine(params, 0.0, 0.0, use_kernel=False,
                                 max_batch=256)
    oracle = ranking_eval.evaluate_oracle(params, topk=topk,
                                          relevance=relevance)
    engine_dense = ranking_eval.evaluate_engine(dense_engine, topk=topk,
                                                relevance=relevance)
    assert engine_dense == oracle, (
        f"engine/oracle divergence at t=0: {engine_dense} vs {oracle}"
    )
    assert oracle.hr == oracle.recall == 1.0, oracle  # by construction
    print(f"# parity at t=0: engine == oracle exactly "
          f"(NDCG@{topk} {oracle.ndcg:.4f}, {oracle.users} users)")

    # ---- 2. pruned-vs-dense ranking gap ------------------------------------
    pruned_engine = ServingEngine(params, t_p, t_q, use_kernel=False,
                                  max_batch=256)
    pruned = ranking_eval.evaluate_engine(pruned_engine, topk=topk,
                                          relevance=relevance)
    gaps = {
        "hr": oracle.hr - pruned.hr,
        "ndcg": oracle.ndcg - pruned.ndcg,
        "recall": oracle.recall - pruned.recall,
    }
    for name, gap in gaps.items():
        emit(f"eval_gap_{name}_at{topk}_rate{rate}", abs(gap) * 1e6,
             f"dense-pruned {name}@{topk} delta")
    print(f"# pruned vs dense @ rate {rate}: NDCG {pruned.ndcg:.4f} vs "
          f"{oracle.ndcg:.4f} (gap {gaps['ndcg']:+.4f}), "
          f"HR {pruned.hr:.4f} vs {oracle.hr:.4f}")

    # ---- 3a. ranking-eval throughput ---------------------------------------
    t0 = time.perf_counter()
    ranking_eval.evaluate_engine(pruned_engine, topk=topk,
                                 relevance=relevance)
    engine_s = time.perf_counter() - t0
    engine_users_s = users.size / engine_s
    emit(f"eval_engine_ranking_u{users.size}_n{n}",
         engine_s / users.size * 1e6, f"{engine_users_s:.0f} users/s")

    batches = ranking_eval.pack_ranking_batches(holdout, 256)

    def scan_eval():
        return mf.eval_ranking_epoch_scan(
            params, batches, t_p, t_q, topk=topk
        )["weight_sum"]

    scan_us = time_fn(scan_eval)
    scan_users_s = users.size / (scan_us / 1e6)
    emit(f"eval_scan_ranking_u{users.size}_n{n}", scan_us / users.size,
         f"{scan_users_s:.0f} users/s")
    print(f"# ranking eval: engine {engine_users_s:.0f} users/s, "
          f"one-scan {scan_users_s:.0f} users/s")

    # ---- 3b. prequential overhead over plain updates -----------------------
    def stream_batches():
        return iter_microbatches(
            ReplaySource(stream_ds, epochs=None, shuffle=True, seed=3),
            128, max_events=stream_events,
        )

    upd = OnlineUpdater(params, t_p=t_p, t_q=t_q, pruning_rate=rate,
                        batch_size=128, seed=5)
    next_b = iter(stream_batches())
    upd.apply(next(next_b))  # compile outside the timed region
    t0 = time.perf_counter()
    done = 0
    for batch in next_b:
        done += upd.apply(batch)["events"]
    plain_s = time.perf_counter() - t0

    upd2 = OnlineUpdater(params, t_p=t_p, t_q=t_q, pruning_rate=rate,
                         batch_size=128, seed=5)
    ev = PrequentialEvaluator(upd2, window=1024)
    next_b = iter(stream_batches())
    ev.consume(next(next_b))
    t0 = time.perf_counter()
    for batch in next_b:
        ev.consume(batch)
    preq_s = time.perf_counter() - t0
    overhead = preq_s / max(plain_s, 1e-9) - 1.0
    emit(f"eval_prequential_b128_n{n}", preq_s / max(done, 1) * 1e6,
         f"{done / preq_s:.0f} events/s, {overhead * 100:.0f}% over plain")
    print(f"# prequential: {done / preq_s:.0f} events/s scored+applied "
          f"({overhead * 100:.0f}% overhead over update-only); "
          f"MAE {ev.stats.mae:.4f}")

    write_json("eval", {
        "shape": {"users": m, "items": n, "k": k, "topk": topk,
                  "pruning_rate": rate},
        "dense": oracle.as_dict(),
        "pruned": pruned.as_dict(),
        "gap_ndcg": gaps["ndcg"],
        "gap_hr": gaps["hr"],
        "gap_recall": gaps["recall"],
        "engine_eval_users_per_s": engine_users_s,
        "scan_eval_users_per_s": scan_users_s,
        "prequential_events_per_s": done / preq_s,
        "prequential_overhead_frac": overhead,
        "prequential_mae": ev.stats.mae,
    })


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--full", action="store_true",
                        help="catalog-scale shape (slower)")
    args = parser.parse_args()
    run(full=args.full)


if __name__ == "__main__":
    main()
