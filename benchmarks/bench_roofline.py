"""Roofline table assembly from the dry-run records (§Roofline deliverable).

Per (arch x shape x mesh): the three roofline terms in seconds, the dominant
bottleneck, MODEL_FLOPS, the useful-FLOP ratio, and the roofline fraction.

Accounting notes (all quantities are PER DEVICE, matching cost_analysis):
  * LM cells are corrected with the two-point depth extrapolation
    (roofline/analysis.extrapolate_depth) because XLA costs scanned layer
    bodies once per program.
  * MODEL_FLOPS uses 6*N_active*T (train) / 2*N_active*T (forward) plus the
    causal-attention term for LM; analytic per-example counts for the MF /
    recsys / GNN families (formulas inline below).
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
from typing import Dict, Optional

from benchmarks.common import emit, reset_records, write_json
from repro import configs as cfg_lib
from repro.roofline import analysis, hw

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")
OUT_DIR = os.path.join(os.path.dirname(__file__), "results")

LM_TOKENS = {
    "train_4k": 256 * 4096,
    "prefill_32k": 32 * 32768,
    "decode_32k": 128,
    "long_500k": 1,
}
LM_KV = {"decode_32k": 32768, "long_500k": 524288}


def _mlp_macs(dims) -> int:
    return sum(a * b for a, b in zip(dims[:-1], dims[1:]))


def model_flops_total(arch: str, shape: str, kind: str) -> Optional[float]:
    """Analytic useful FLOPs for the whole step (all devices)."""
    cfg = cfg_lib.get_config(arch)
    if arch in ("gemma-7b", "qwen1.5-4b", "qwen3-4b", "deepseek-v2-lite-16b",
                "granite-moe-1b-a400m"):
        tokens = LM_TOKENS[shape]
        n_act = cfg.active_param_count()
        if kind == "train":
            base = 6.0 * n_act * tokens
            attn = 6.0 * tokens * cfg.n_layers * cfg.n_heads * cfg.head_dim * 4096
            return base + attn
        if shape in LM_KV:  # decode: params fwd + attention over the cache
            s = LM_KV[shape]
            attn = 4.0 * tokens * cfg.n_layers * cfg.n_heads * cfg.head_dim * s
            return 2.0 * n_act * tokens + attn
        # prefill: forward + causal attention (avg context S/2)
        attn = 2.0 * tokens * cfg.n_layers * cfg.n_heads * cfg.head_dim * 32768
        return 2.0 * n_act * tokens + attn

    if arch == "dpmf":
        if kind == "train":  # train_1m and train_1m_sm
            return 6.0 * cfg.k * 1_048_576
        return 2.0 * 1024 * cfg.num_items * cfg.k  # serve_top100

    if arch == "fm":
        f, k = cfg.n_fields, cfg.embed_dim
        batches = {"train_batch": 65536, "serve_p99": 512,
                   "serve_bulk": 262144}
        if shape == "retrieval_cand":
            return 2.0 * 1_000_000 * k + 4.0 * (f - 1) * k
        b = batches[shape]
        fwd = 4.0 * f * k * b
        return 3.0 * fwd if kind == "train" else fwd

    if arch == "dlrm-mlperf":
        per_ex = 2.0 * (
            _mlp_macs((cfg.n_dense,) + cfg.bot_mlp)
            + _mlp_macs((cfg.bot_mlp[-1] + cfg.n_interact,) + cfg.top_mlp)
            + (cfg.n_sparse + 1) ** 2 * cfg.embed_dim // 2
        )
        sizes = {"train_batch": 65536, "serve_p99": 512,
                 "serve_bulk": 262144, "retrieval_cand": 1_000_000}
        b = sizes[shape]
        return (3.0 if kind == "train" else 1.0) * per_ex * b

    if arch == "sasrec":
        d, s = cfg.embed_dim, cfg.seq_len
        per_tok = 2.0 * cfg.n_blocks * (6 * d * d + 2 * s * d)
        sizes = {"train_batch": 65536, "serve_p99": 512, "serve_bulk": 262144,
                 "retrieval_cand": 1}
        b = sizes[shape]
        enc = per_tok * s * b
        if shape == "retrieval_cand":
            return enc + 2.0 * 1_000_000 * d
        if kind == "train":
            return 3.0 * (enc + 2.0 * 2 * s * d * b)
        return enc + 2.0 * b * (cfg.n_items + 1) * d  # catalog scoring

    if arch == "bst":
        d, s = cfg.embed_dim, cfg.seq_len + 1
        per_ex = 2.0 * cfg.n_blocks * s * (6 * d * d + 2 * s * d) + 2.0 * _mlp_macs(
            (s * d + cfg.n_profile,) + cfg.mlp_dims + (1,)
        )
        sizes = {"train_batch": 65536, "serve_p99": 512, "serve_bulk": 262144,
                 "retrieval_cand": 1_000_000}
        b = sizes[shape]
        return (3.0 if kind == "train" else 1.0) * per_ex * b

    if arch == "gat-cora":
        graphs = {
            "full_graph_sm": (2708, 10556, 1433, 7),
            "minibatch_lg": (1024 * 166, 1024 * 165, 602, 41),
            "ogb_products": (2449029, 61859140, 100, 47),
            "molecule": (128 * 30, 128 * 64, 32, 8),
        }
        n, e, d_feat, n_cls = graphs[shape]
        h, dh = cfg.n_heads, cfg.d_hidden
        l1 = 2.0 * n * d_feat * h * dh + 6.0 * e * h * dh
        l2 = 2.0 * n * (h * dh) * n_cls + 6.0 * e * n_cls
        return 3.0 * (l1 + l2)  # train

    return None


def _load(
    arch: str, shape: str, tag: str, calib: int = 0, variant: str = ""
) -> Optional[Dict]:
    suffix = (f"__v-{variant}" if variant else "") + (
        f"__calib{calib}" if calib else ""
    )
    safe = arch.replace("/", "_").replace(".", "_")
    path = os.path.join(RESULTS_DIR, f"{safe}__{shape}__{tag}{suffix}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        rec = json.load(f)
    return rec if rec.get("status") == "ok" else None


# §Perf hillclimbed cells: (arch, shape) -> best beyond-paper variant.
# (dpmf's optimized step is its own cell, train_1m_sm.)
BEST_VARIANTS = {
    ("deepseek-v2-lite-16b", "train_4k"): "moe_sm2",
    ("gemma-7b", "train_4k"): "remat_dots",
    ("granite-moe-1b-a400m", "train_4k"): "moe_sm",  # bonus: same fix as deepseek
}


@dataclasses.dataclass
class Row:
    arch: str
    shape: str
    mesh: str
    kind: str
    chips: int
    flops_dev: float
    bytes_dev: float
    coll_dev: float
    terms: Dict[str, float]
    corrected: bool
    variant: str = ""


def _one_row(arch, shape, mesh_tag, chips, variant=""):
    rec = _load(arch, shape, mesh_tag, variant=variant)
    if rec is None:
        return None
    flops = rec.get("cost", {}).get("flops", 0.0) or 0.0
    byts = rec.get("cost", {}).get("bytes_accessed", 0.0) or 0.0
    coll = rec.get("collectives", {}).get("total_bytes", 0.0) or 0.0
    corrected = False

    c1 = _load(arch, shape, mesh_tag, calib=1, variant=variant)
    c2 = _load(arch, shape, mesh_tag, calib=2, variant=variant)
    if c1 and c2:
        cfg = cfg_lib.get_config(arch)
        fix = analysis.extrapolate_depth(c1, c2, cfg.scan_layers)
        flops, byts = fix["flops"], fix["bytes_accessed"]
        coll = fix["collective_bytes"]
        corrected = True

    mf_total = model_flops_total(arch, shape, rec.get("kind", ""))
    terms = analysis.roofline_terms(
        flops * chips, byts * chips, coll * chips, chips,
        model_flops=mf_total,
    )
    return Row(arch, shape, mesh_tag, rec.get("kind", ""), chips,
               flops, byts, coll, terms, corrected, variant)


def build_rows(mesh_tag: str = "singlepod"):
    chips = hw.CHIPS_SINGLE_POD if mesh_tag == "singlepod" else hw.CHIPS_MULTI_POD
    rows = []
    for arch, shape in cfg_lib.all_cells(include_dpmf=True):
        row = _one_row(arch, shape, mesh_tag, chips)
        if row is None:
            continue
        rows.append(row)
        variant = BEST_VARIANTS.get((arch, shape))
        if variant:
            vrow = _one_row(arch, shape, mesh_tag, chips, variant=variant)
            if vrow is not None:
                rows.append(vrow)
    return rows


def render_markdown(rows) -> str:
    hdr = (
        "| arch | shape | kind | compute_s | memory_s | collective_s | "
        "dominant | useful/HLO | roofline_frac |\n"
        "|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        t = r.terms
        uf = t.get("useful_flop_fraction")
        rf = t.get("roofline_fraction")
        name = r.arch + (f" [{r.variant}]" if r.variant else "")
        lines.append(
            f"| {name} | {r.shape} | {r.kind} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | {t['dominant']} | "
            f"{uf:.3f} | {rf:.3f} |" if uf is not None else
            f"| {name} | {r.shape} | {r.kind} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | {t['dominant']} | "
            f"- | - |"
        )
    return hdr + "\n".join(lines) + "\n"


def run(*, full: bool = False, smoke: bool = False) -> None:
    # The roofline table only *reads* recorded dry-run costs, so smoke and
    # full are the same cheap assembly pass.
    del full, smoke
    reset_records()
    os.makedirs(OUT_DIR, exist_ok=True)
    for tag in ("singlepod", "multipod"):
        rows = build_rows(tag)
        if not rows:
            emit(f"roofline/{tag}", 0.0, "no dry-run records found")
            continue
        md = render_markdown(rows)
        out = os.path.join(OUT_DIR, f"roofline_{tag}.md")
        with open(out, "w") as f:
            f.write(md)
        with open(os.path.join(OUT_DIR, f"roofline_{tag}.json"), "w") as f:
            json.dump(
                [dataclasses.asdict(r) for r in rows], f, indent=2, default=str
            )
        for r in rows:
            rf = r.terms.get("roofline_fraction")
            suffix = f"[{r.variant}]" if r.variant else ""
            emit(
                f"roofline/{tag}/{r.arch}{suffix}/{r.shape}",
                r.terms["bound_s"] * 1e6,
                f"dominant={r.terms['dominant']}"
                + (f";roofline_frac={rf:.3f}" if rf is not None else "")
                + (";depth-corrected" if r.corrected else ""),
            )
        emit(f"roofline/{tag}/table", 0.0, out)
    write_json("roofline")
