"""Online learning subsystem: update throughput, swap latency, serving under
concurrent model refresh.

    PYTHONPATH=src python -m benchmarks.bench_online [--full]

Four claims, checked then timed:

1. **pruned incremental updates do less work** — the streamed row updates
   run with ``work_fraction < 1`` of the dense MACs at pruning_rate > 0
   (and the wall-clock per event is emitted for both);
2. **swap latency** — a touched-rows-only hot swap is O(touched * k), not
   O(n * k): both the incremental swap and a forced full-rebuild swap are
   timed;
3. **freshness is free at the request path** — serving p50/p99 with the
   updater + publisher running concurrently vs. an idle model, same engine,
   same traffic;
4. **no dropped requests** — every request issued during the concurrent
   phase must complete (asserted, same contract as the CI smoke job).

Emits the ``name,us_per_call,derived`` CSV contract and writes
``BENCH_online.json``.
"""
from __future__ import annotations

import argparse
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import jax

from benchmarks.common import emit, reset_records, write_json
from repro.core import mf, threshold
from repro.online import (
    OnlineUpdater,
    PoissonSource,
    SnapshotPublisher,
    iter_microbatches,
)
from repro.serving import ServingEngine


def _updater_for(params, t_p, t_q, rate, batch):
    return OnlineUpdater(
        params, None, t_p, t_q,
        optimizer="adagrad", lr=0.02, pruning_rate=rate,
        batch_size=batch, seed=7,
    )


def run(*, full: bool = False, smoke: bool = False) -> None:
    reset_records()
    if smoke:
        m, n, k = 512, 4000, 16
        batch_events, n_batches, rate = 128, 8, 0.5
    elif full:
        m, n, k = 20000, 100000, 64
        batch_events, n_batches, rate = 256, 24, 0.5
    else:
        m, n, k = 2048, 20000, 48
        batch_events, n_batches, rate = 256, 24, 0.5
    rng = np.random.default_rng(0)

    params = mf.init_params(jax.random.PRNGKey(0), m, n, k)
    t_p, t_q = threshold.thresholds_from_matrices(params.p, params.q, rate)

    def event_batch_iter(seed, count=n_batches):
        src = PoissonSource(m, n, rate=1e4, seed=seed)
        return iter_microbatches(
            src, batch_events, max_events=batch_events * count
        )

    def event_batches(seed):
        return list(event_batch_iter(seed))

    # ---- update throughput: pruned vs dense --------------------------------
    results = {}
    for name, tp_, tq_ in (("pruned", t_p, t_q), ("dense", 0.0, 0.0)):
        upd = _updater_for(params, tp_, tq_, rate if name == "pruned" else 0.0,
                           batch_events)
        batches = event_batches(3)
        upd.apply(batches[0])  # compile outside the timed region
        start = time.perf_counter()
        for b in batches[1:]:
            upd.apply(b)
        jax.block_until_ready(upd.params.p)
        dt = time.perf_counter() - start
        ev = sum(len(b) for b in batches[1:])
        results[name] = (ev / dt, upd.mean_work_fraction)
        emit(f"online_update_{name}_b{batch_events}_n{n}", dt / ev * 1e6,
             f"{ev / dt:.0f} events/s")
    pruned_rate, pruned_work = results["pruned"]
    dense_rate, _ = results["dense"]
    emit(f"online_update_work_fraction_n{n}", pruned_work * 1e6,
         f"{pruned_work:.3f} of dense MACs")
    print(f"# pruned updates: work_fraction {pruned_work:.3f} "
          f"({pruned_rate:.0f} events/s vs {dense_rate:.0f} dense)")
    assert pruned_work < 1.0, "pruned online updates must skip work"

    # ---- swap latency ------------------------------------------------------
    upd = _updater_for(params, t_p, t_q, rate, batch_events)
    engine = ServingEngine(params, t_p, t_q, use_kernel=False, max_batch=64)
    engine.topk([0], 10)  # build the layout the swaps will patch
    pub = SnapshotPublisher(engine, upd)
    incr = []
    for b in event_batches(5):
        upd.apply(b)
        incr.append(pub.publish().swap_s)
    incr_ms = float(np.median(incr[2:]) * 1e3)  # skip scatter-compile swaps
    upd.apply(next(event_batch_iter(6, count=1)))
    # a forced recalibration marks the snapshot dirty through the public
    # maintenance API, driving the full-rebuild swap path
    assert upd.maybe_recalibrate(force=True) is not None
    full_ms = pub.publish().swap_s * 1e3
    emit(f"online_swap_incremental_n{n}", incr_ms * 1e3, "ms -> us")
    emit(f"online_swap_full_rebuild_n{n}", full_ms * 1e3, "ms -> us")
    print(f"# swap latency: incremental {incr_ms:.1f} ms vs full rebuild "
          f"{full_ms:.1f} ms (catalog {n} items)")

    # ---- serving percentiles, idle vs under concurrent refresh -------------
    def hammer(n_req, conc, topk=10):
        users = rng.integers(0, m, n_req)
        lat = np.empty(n_req)

        def one(iu):
            i, u = iu
            t0 = time.perf_counter()
            engine.submit(int(u), topk, timeout=60).result(timeout=120)
            lat[i] = time.perf_counter() - t0

        with ThreadPoolExecutor(max_workers=conc) as pool:
            list(pool.map(one, enumerate(users)))
        return np.percentile(lat * 1e3, [50, 99])

    for b_ in (1, 2, 4, 8, 16, 32, 64):
        engine.topk(list(range(b_)), 10)  # warm the queue's buckets
    engine.start(linger_ms=1.0)
    n_req, conc = (2048, 32) if full else (512, 16)
    idle_p50, idle_p99 = hammer(n_req, conc)

    stop = threading.Event()
    refresh_error = []

    def refresher():
        try:
            batches = iter_microbatches(
                PoissonSource(m, n, rate=1e4, seed=11), batch_events
            )
            for b in batches:
                if stop.is_set():
                    return
                upd.apply(b)
                pub.publish()
        except Exception as exc:  # noqa: BLE001 - surfaced after the join
            refresh_error.append(exc)

    thread = threading.Thread(target=refresher, daemon=True)
    thread.start()
    live_p50, live_p99 = hammer(n_req, conc)
    stop.set()
    thread.join(timeout=300)
    engine.stop()
    assert not refresh_error, refresh_error
    swaps_during = len(pub.reports)

    emit(f"online_serve_idle_p99_c{conc}", idle_p99 * 1e3,
         f"p50 {idle_p50:.2f} ms")
    emit(f"online_serve_refresh_p99_c{conc}", live_p99 * 1e3,
         f"p50 {live_p50:.2f} ms, {swaps_during} swaps total")
    print(f"# serving under refresh: p50 {live_p50:.2f} ms / p99 "
          f"{live_p99:.2f} ms (idle: {idle_p50:.2f} / {idle_p99:.2f}); "
          f"0 of {2 * n_req} requests dropped")

    write_json("online", {
        "shape": {"users": m, "items": n, "k": k,
                  "batch_events": batch_events},
        "update_events_per_s_pruned": pruned_rate,
        "update_events_per_s_dense": dense_rate,
        "work_fraction": pruned_work,
        "swap_ms_incremental": incr_ms,
        "swap_ms_full_rebuild": full_ms,
        "serve_idle_ms_p50": float(idle_p50),
        "serve_idle_ms_p99": float(idle_p99),
        "serve_refresh_ms_p50": float(live_p50),
        "serve_refresh_ms_p99": float(live_p99),
        "requests_dropped": 0,
    })


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--full", action="store_true",
                        help="catalog-scale shape (slower)")
    args = parser.parse_args()
    run(full=args.full)


if __name__ == "__main__":
    main()
