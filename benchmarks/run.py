"""Benchmark entry point: one function per paper table/figure plus the
roofline assembly.  Prints ``name,us_per_call,derived`` CSV lines.

    PYTHONPATH=src python -m benchmarks.run [--full | --smoke] \
        [--only fig11,roofline]

``--smoke`` runs every suite at toy size and schema-validates each
``BENCH_<suite>.json`` report (benchmarks/common.validate_report) — the CI
guard that keeps the machine-readable perf trajectory from regressing to
empty or malformed.
"""
from __future__ import annotations

import argparse
import os
import sys
import traceback


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--full", action="store_true",
                        help="paper-scale datasets/epochs (slow)")
    parser.add_argument("--smoke", action="store_true",
                        help="toy sizes; schema-validate every "
                             "BENCH_<suite>.json report")
    parser.add_argument("--only", default=None,
                        help="comma-separated subset: "
                             "figures,kernels,roofline,serving,online,"
                             "training,eval,fleet,slo,scale,chaos,"
                             "workloads")
    parser.add_argument("--json-dir", default=None,
                        help="directory for the BENCH_<suite>.json reports "
                             "(default: $BENCH_JSON_DIR or CWD)")
    args = parser.parse_args()
    if args.full and args.smoke:
        parser.error("--full and --smoke are mutually exclusive")
    if args.json_dir:
        os.environ["BENCH_JSON_DIR"] = args.json_dir

    from benchmarks import (
        bench_chaos,
        bench_eval,
        bench_fleet,
        bench_kernels,
        bench_online,
        bench_paper_figures,
        bench_roofline,
        bench_scale,
        bench_serving,
        bench_slo,
        bench_training,
        bench_workloads,
        common,
    )

    suites = {
        "figures": bench_paper_figures.run,
        "kernels": bench_kernels.run,
        "roofline": bench_roofline.run,
        "serving": bench_serving.run,
        "online": bench_online.run,
        "training": bench_training.run,
        "eval": bench_eval.run,
        "fleet": bench_fleet.run,
        "slo": bench_slo.run,
        "scale": bench_scale.run,
        "chaos": bench_chaos.run,
        "workloads": bench_workloads.run,
    }
    selected = (
        {s.strip() for s in args.only.split(",")} if args.only else set(suites)
    )
    unknown = selected - set(suites)
    if unknown:
        parser.error(
            f"unknown suite(s) {sorted(unknown)}; "
            f"choose from {sorted(suites)}"
        )
    json_dir = os.environ.get("BENCH_JSON_DIR") or "."
    failed = 0
    for name, fn in suites.items():
        if name not in selected:
            continue
        try:
            fn(full=args.full, smoke=args.smoke)
        except Exception:
            failed += 1
            print(f"bench/{name},0.0,ERROR", flush=True)
            traceback.print_exc()
            continue
        if args.smoke:
            report = os.path.join(json_dir, f"BENCH_{name}.json")
            try:
                common.validate_report(report)
                print(f"# schema OK: {report}")
            except ValueError as exc:
                failed += 1
                print(f"bench/{name},0.0,SCHEMA_ERROR {exc}", flush=True)
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
