"""Benchmark entry point: one function per paper table/figure plus the
roofline assembly.  Prints ``name,us_per_call,derived`` CSV lines.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig11,roofline]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--full", action="store_true",
                        help="paper-scale datasets/epochs (slow)")
    parser.add_argument("--only", default=None,
                        help="comma-separated subset: "
                             "figures,kernels,roofline,serving,online")
    parser.add_argument("--json-dir", default=None,
                        help="directory for the BENCH_<suite>.json reports "
                             "(default: $BENCH_JSON_DIR or CWD)")
    args = parser.parse_args()
    if args.json_dir:
        import os

        os.environ["BENCH_JSON_DIR"] = args.json_dir

    from benchmarks import (
        bench_kernels,
        bench_online,
        bench_paper_figures,
        bench_roofline,
        bench_serving,
    )

    suites = {
        "figures": bench_paper_figures.run,
        "kernels": bench_kernels.run,
        "roofline": bench_roofline.run,
        "serving": bench_serving.run,
        "online": bench_online.run,
    }
    selected = (
        {s.strip() for s in args.only.split(",")} if args.only else set(suites)
    )
    failed = 0
    for name, fn in suites.items():
        if name not in selected:
            continue
        try:
            fn(full=args.full)
        except Exception:
            failed += 1
            print(f"bench/{name},0.0,ERROR", flush=True)
            traceback.print_exc()
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
