"""Serving fleet: routing policies, rolling refresh, delta compression.

    PYTHONPATH=src python -m benchmarks.bench_fleet [--full]

Four claims, checked then timed:

1. **lossless delta compression shrinks the wire** — one publish's
   touched-row payload, compressed (byte-shuffle + DEFLATE) vs raw bytes;
   the codec round trip is bit-exact (asserted) and its throughput is
   timed;
2. **cache-aware routing keeps replica caches hot** — the same
   hot-user-skewed traffic through an affinity router vs a random router
   over the same fleet (per-replica cache capacity sized *below* the hot
   set, so random routing thrashes): hot-user cache hit rate must be
   higher under affinity;
3. **router throughput** — the same request mix through one engine vs a
   routed local fleet (recorded, not asserted: in one CPU process the
   replicas share cores, so this measures routing overhead, not scale-out);
4. **rolling refresh doesn't drop requests** — latency p50/p99 of
   concurrent traffic while the publisher ships rolling delta updates
   across the fleet; zero failed requests asserted, every replica must
   converge to the final published version.

Emits the ``name,us_per_call,derived`` CSV contract and writes
``BENCH_fleet.json`` (summary schema documented in
``docs/architecture.md``).
"""
from __future__ import annotations

import argparse
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import jax

from benchmarks.common import emit, reset_records, write_json
from repro.core import mf
from repro.distributed.compression import compress_array, decompress_array
from repro.online import OnlineUpdater, PoissonSource, SnapshotPublisher, iter_microbatches
from repro.serving import ServingEngine
from repro.serving.fleet import ServingFleet, make_message


def _hot_traffic(rng, num_users, n_requests, hot_users, hot_frac=0.8):
    """Request stream where ``hot_frac`` of requests hit the hot set."""
    hot = rng.random(n_requests) < hot_frac
    users = rng.integers(0, num_users, n_requests)
    users[hot] = hot_users[rng.integers(0, len(hot_users), int(hot.sum()))]
    return users


def _drive(frontend, users, topk, clients=8, timeout=60.0):
    """Submit every user id through ``clients`` threads; returns
    (wall_seconds, latencies_ms, failures)."""
    latencies = np.empty(len(users))
    failures = []

    def one(iu):
        i, u = iu
        t0 = time.perf_counter()
        try:
            frontend.submit(int(u), topk, timeout=timeout).result(timeout)
            latencies[i] = (time.perf_counter() - t0) * 1e3
        except Exception as exc:  # noqa: BLE001 - any failure is a drop
            latencies[i] = np.nan
            failures.append(repr(exc))

    start = time.perf_counter()
    with ThreadPoolExecutor(max_workers=clients) as pool:
        list(pool.map(one, enumerate(users)))
    return time.perf_counter() - start, latencies, failures


def run(*, full: bool = False, smoke: bool = False) -> None:
    """Run the fleet suite at smoke/default/full scale."""
    reset_records()
    if smoke:
        m, n, k = 400, 3000, 16
        n_requests, replicas = 200, 2
        hot_set, cache_size = 96, 48
        stream_batches = 4
    elif full:
        m, n, k = 8000, 60000, 32
        n_requests, replicas = 2000, 4
        hot_set, cache_size = 512, 128
        stream_batches = 12
    else:
        m, n, k = 2000, 20000, 24
        n_requests, replicas = 800, 3
        hot_set, cache_size = 256, 96
        stream_batches = 8
    topk = 10
    rng = np.random.default_rng(0)
    summary = {}

    # ---- 1. delta compression: wire bytes, ratio, round-trip ---------------
    params = mf.init_params(jax.random.PRNGKey(0), m, n, k)
    upd = OnlineUpdater(params, None, 0.0, 0.0, batch_size=256, seed=3)
    src = PoissonSource(m, n, rate=1e4, seed=3)
    for batch in iter_microbatches(src, 256, max_events=1024):
        upd.apply(batch)
    snap = upd.snapshot()
    msg = make_message(snap, 1, 0, full=False, compress=True)
    ratio = msg.raw_bytes / max(msg.wire_bytes, 1)
    emit("fleet_delta_wire_KB", msg.wire_bytes / 1024.0,
         f"raw_KB={msg.raw_bytes / 1024.0:.1f} ratio={ratio:.2f}")
    rows = np.asarray(snap.params.q[:1024])
    reps = 5
    t0 = time.perf_counter()
    for _ in range(reps):
        c = compress_array(rows)
    t_c = (time.perf_counter() - t0) / reps
    t0 = time.perf_counter()
    for _ in range(reps):
        back = decompress_array(c)
    t_d = (time.perf_counter() - t0) / reps
    assert np.array_equal(back, rows), "lossless codec must round-trip bit-exact"
    emit("fleet_compress_MBps", rows.nbytes / t_c / 1e6 if t_c else 0.0,
         f"decompress_MBps={rows.nbytes / t_d / 1e6:.0f}")
    summary["wire_bytes"] = int(msg.wire_bytes)
    summary["raw_bytes"] = int(msg.raw_bytes)
    summary["compression_ratio"] = round(ratio, 3)

    # ---- 2. affinity vs random routing: hot-user cache hit rate ------------
    # SVD++ so the per-replica hot-user LRU is live; capacity below the hot
    # set means a replica can only stay warm if the router keeps sending it
    # the same users.
    sv_params = mf.init_params(
        jax.random.PRNGKey(1), m, n, k, variant="svdpp"
    )
    history = rng.integers(0, n, (m, 8)).astype(np.int32)
    hot_users = rng.choice(m, hot_set, replace=False)
    users = _hot_traffic(rng, m, n_requests, hot_users)
    hit_rates = {}
    for policy in ("affinity", "random"):
        fleet = ServingFleet(
            sv_params, 0.0, 0.0,
            replicas=replicas, backend="local", user_history=history,
            engine_kwargs={"cache_size": cache_size},
            queue_kwargs={"linger_ms": 0.5},
            router_kwargs={"policy": policy},
        )
        wall, lat, failures = _drive(fleet, users, topk)
        stats = fleet.stats()
        hits = sum(r["cache_hits"] for r in stats["replicas"])
        misses = sum(r["cache_misses"] for r in stats["replicas"])
        fleet.close()
        assert not failures, f"{policy}: dropped requests {failures[:3]}"
        rate = hits / max(hits + misses, 1)
        hit_rates[policy] = rate
        emit(f"fleet_route_{policy}_req_s", len(users) / wall,
             f"cache_hit_rate={rate:.3f}")
    summary["cache_hit_rate_affinity"] = round(hit_rates["affinity"], 4)
    summary["cache_hit_rate_random"] = round(hit_rates["random"], 4)
    summary["affinity_beats_random"] = bool(
        hit_rates["affinity"] > hit_rates["random"]
    )

    # ---- 3. router throughput vs single engine -----------------------------
    base = mf.init_params(jax.random.PRNGKey(2), m, n, k, variant="bias",
                          global_mean=3.5)
    mix = rng.integers(0, m, n_requests)
    engine = ServingEngine(base, 0.0, 0.0)
    engine.start(linger_ms=0.5)
    engine.topk(mix[:8], topk)  # warm a bucket
    wall_1, lat_1, failures = _drive(engine, mix, topk)
    engine.stop()
    assert not failures
    emit("fleet_single_engine_req_s", n_requests / wall_1,
         f"p99_ms={np.nanpercentile(lat_1, 99):.2f}")
    fleet = ServingFleet(base, 0.0, 0.0, replicas=replicas, backend="local",
                         queue_kwargs={"linger_ms": 0.5})
    wall_r, lat_r, failures = _drive(fleet, mix, topk)
    fleet.close()
    assert not failures
    emit("fleet_routed_req_s", n_requests / wall_r,
         f"replicas={replicas} p99_ms={np.nanpercentile(lat_r, 99):.2f}")
    summary["single_engine_req_s"] = round(n_requests / wall_1, 1)
    summary["routed_req_s"] = round(n_requests / wall_r, 1)
    summary["replicas"] = replicas

    # ---- 4. rolling refresh under load -------------------------------------
    upd = OnlineUpdater(base, None, 0.0, 0.0, batch_size=256, seed=5)
    fleet = ServingFleet(base, 0.0, 0.0, replicas=replicas, backend="local",
                         queue_kwargs={"linger_ms": 0.5})
    pub = SnapshotPublisher(None, upd, compress=True)
    pub.subscribe(fleet.router)
    src = PoissonSource(m, n, rate=1e4, seed=5)
    batches = list(iter_microbatches(src, 256,
                                     max_events=256 * stream_batches))
    swap_ms = []

    def refresher():
        for batch in batches:
            upd.apply(batch)
            t0 = time.perf_counter()
            pub.publish()
            swap_ms.append((time.perf_counter() - t0) * 1e3)

    worker = __import__("threading").Thread(target=refresher, daemon=True)
    worker.start()
    wall, lat, failures = _drive(fleet, mix, topk)
    worker.join(timeout=300)
    versions = [r.version for r in fleet.replicas]
    fleet.close()
    assert not failures, f"rolling refresh dropped requests: {failures[:3]}"
    assert all(v == pub.version for v in versions), (
        f"fleet diverged: {versions} != published v{pub.version}"
    )
    emit("fleet_rolling_p99_ms", float(np.nanpercentile(lat, 99)),
         f"p50_ms={np.nanpercentile(lat, 50):.2f} swaps={len(swap_ms)}")
    emit("fleet_rolling_swap_ms_p50", float(np.percentile(swap_ms, 50)),
         f"max={max(swap_ms):.1f}")
    summary["rolling_p99_ms"] = round(float(np.nanpercentile(lat, 99)), 3)
    summary["rolling_swaps"] = len(swap_ms)
    summary["rolling_dropped"] = 0
    summary["final_versions"] = versions
    summary["zero_dropped"] = True

    write_json("fleet", summary)


def main() -> None:
    """CLI entry point."""
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--full", action="store_true")
    parser.add_argument("--smoke", action="store_true")
    args = parser.parse_args()
    run(full=args.full, smoke=args.smoke)


if __name__ == "__main__":
    main()
