"""SLO-aware degradation: the throughput/quality frontier + overload replay.

    PYTHONPATH=src python -m benchmarks.bench_slo [--full | --smoke]

Two claims, checked then recorded in ``BENCH_slo.json``:

1. **frontier** — sweeping the pruning rate through ``threshold_for_rate``
   (the Eq. 7/8 solve) trades ranking quality for serving throughput
   *monotonically*: each tighter operating point serves strictly more
   req/s and never a higher NDCG@K against the dense oracle.  The rate-0
   point is the exactness anchor: identical indices to dense, NDCG 1.0.
2. **overload** — an open-loop arrival stream at ~1.3x the dense engine's
   capacity.  A fixed dense threshold lets the backlog (and p99) grow
   without bound; the closed-loop :class:`~repro.serving.slo.SLOController`
   degrades the thresholds until capacity exceeds arrival, holding the
   steady-state p99 (back half of completions) under the budget with zero
   dropped or failed requests.
"""
from __future__ import annotations

import argparse
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, reset_records, time_fn, write_json
from repro.core import mf
from repro.core.threshold import measure_stats, threshold_for_rate
from repro.serving import ServingEngine, SLOConfig, SLOController


def _ndcg_vs_dense(pruned_idx: np.ndarray, dense_idx: np.ndarray) -> float:
    """Mean NDCG@K of the pruned lists with the dense top-k as the binary
    relevant set — 1.0 iff every list matches the oracle set in order-of-
    relevance terms, monotonically lower as pruning evicts true items."""
    k = dense_idx.shape[1]
    discounts = 1.0 / np.log2(np.arange(2, k + 2))
    ideal = float(discounts.sum())
    scores = []
    for row_p, row_d in zip(pruned_idx, dense_idx):
        rel = np.isin(row_p, row_d).astype(np.float64)
        scores.append(float((rel * discounts[: len(rel)]).sum()) / ideal)
    return float(np.mean(scores))


def _dense_topk(params, users, topk):
    scores = np.asarray(params.p[users] @ params.q.T)
    if params.item_bias is not None:
        scores = scores + np.asarray(params.item_bias)[None, :]
    idx = np.argsort(-scores, axis=1, kind="stable")[:, :topk]
    return idx


def _spectral_params(m, n, k, decay=0.93):
    """MF factors with a decaying latent spectrum (what trained models look
    like: leading dimensions carry most of the energy).  Pruning then evicts
    the low-magnitude tail dimensions first, so quality degrades gracefully
    and the compacted latent width genuinely shrinks with the rate — iid
    Gaussian factors have neither property."""
    base = mf.init_params(jax.random.PRNGKey(0), m, n, k, variant="plain")
    scale = jnp.asarray(decay ** np.arange(k), jnp.float32)[None, :]
    return base._replace(p=base.p * scale, q=base.q * scale)


def _frontier(*, m, n, k, batch, topk, rates):
    """Part 1: one engine per rate (latent-axis compaction on, so pruning
    actually sheds FLOPs), timed on the same request batch.

    The rates are chosen to land the compacted latent width at k, ~2k/3 and
    ~k/3 under the spectral decay, so the operating points differ in FLOPs,
    not just threshold value.  Each point takes the best of three timing
    rounds: scheduler noise only ever inflates a wall-clock sample, so
    min-of-rounds is the robust capacity estimate."""
    params = _spectral_params(m, n, k, decay=0.97)
    users = np.random.default_rng(0).integers(0, m, batch)
    dense_idx = _dense_topk(params, users, topk)
    sp, sq = measure_stats(params.p), measure_stats(params.q)

    points = []
    for rate in rates:
        t_p = threshold_for_rate(sp, rate)
        t_q = threshold_for_rate(sq, rate)
        engine = ServingEngine(params, t_p, t_q, use_kernel=False,
                               max_batch=batch, compact_latent=True)
        us = min(
            time_fn(lambda e=engine: e.topk(users, topk)[0], iters=5)
            for _ in range(3)
        )
        _, idx = engine.topk(users, topk)
        ndcg = _ndcg_vs_dense(np.asarray(idx), dense_idx)
        req_s = batch / (us / 1e6)
        if rate <= 0.0:
            assert np.array_equal(np.asarray(idx), dense_idx), (
                "rate=0 must be exactly the dense oracle"
            )
            assert ndcg == 1.0
        points.append({
            "rate": float(rate),
            "t_q": float(t_q),
            "us_per_batch": us,
            "req_per_s": req_s,
            "ndcg": ndcg,
        })
        emit(f"slo/frontier_rate{rate:.2f}", us,
             f"req_s={req_s:.1f} ndcg={ndcg:.4f}")

    for lo, hi in zip(points, points[1:]):
        assert hi["req_per_s"] > lo["req_per_s"], (
            f"frontier not monotone in throughput: "
            f"{lo['rate']}->{hi['rate']} gave "
            f"{lo['req_per_s']:.1f}->{hi['req_per_s']:.1f} req/s"
        )
        assert hi["ndcg"] <= lo["ndcg"] + 1e-9, (
            f"pruning harder must never raise NDCG: "
            f"{lo['rate']}->{hi['rate']} gave "
            f"{lo['ndcg']:.4f}->{hi['ndcg']:.4f}"
        )
    print(f"# frontier OK: {len(points)} monotone operating points")
    return points


def _open_loop(engine, *, n_requests, interval_s, topk, controller=None):
    """Submit single-user requests on a fixed clock (open loop: arrivals
    don't wait for completions), return completion latencies in seconds."""
    rng = np.random.default_rng(1)
    users = rng.integers(0, engine.num_users, n_requests)
    latencies = np.full(n_requests, np.nan)
    failures = []
    done = threading.Semaphore(0)

    stop_tick = threading.Event()

    def ticker():
        while not stop_tick.is_set():
            controller.maybe_tick()
            stop_tick.wait(controller.config.tick_interval_s / 4)

    tick_thread = None
    if controller is not None:
        tick_thread = threading.Thread(target=ticker, daemon=True)
        tick_thread.start()

    next_at = time.perf_counter()
    for i, u in enumerate(users):
        now = time.perf_counter()
        if now < next_at:
            time.sleep(next_at - now)
        next_at += interval_s
        t0 = time.perf_counter()

        def _done(fut, i=i, t0=t0):
            try:
                fut.result()
                latencies[i] = time.perf_counter() - t0
            except Exception as exc:  # noqa: BLE001 - any failure counts
                failures.append(repr(exc))
            done.release()

        try:
            engine.submit(int(u), topk, timeout=60.0).add_done_callback(_done)
        except Exception as exc:  # noqa: BLE001
            failures.append(repr(exc))
            done.release()
    for _ in range(n_requests):
        done.acquire()
    if tick_thread is not None:
        stop_tick.set()
        tick_thread.join(10)
    return latencies, failures


def _overload(*, m, n, k, topk, duration_s, max_batch):
    """Part 2: fixed dense threshold vs the closed loop, same arrivals."""
    params = _spectral_params(m, n, k, decay=0.9)
    max_rate = 0.85
    users = np.arange(max_batch)

    def _warm(engine):
        # every power-of-two bucket the queue can coalesce into — a mid-run
        # bucket compile stall is not the claim under test
        for b in (1, 2, 4, 8, 16, 32, 64):
            if b <= max_batch:
                engine.topk(users[:b], topk)

    probe = ServingEngine(params, 0.0, 0.0, use_kernel=False,
                          max_batch=max_batch, compact_latent=True)
    _warm(probe)
    dense_us = time_fn(lambda: probe.topk(users, topk)[0], iters=5)
    probe.stop()
    # probe the max-degradation operating point too: reports the capacity
    # headroom AND warms the XLA cache for the compacted shapes the
    # controller will swap to
    sp, sq = measure_stats(params.p), measure_stats(params.q)
    t_p85 = threshold_for_rate(sp, max_rate)
    t_q85 = threshold_for_rate(sq, max_rate)
    probe = ServingEngine(params, t_p85, t_q85, use_kernel=False,
                          max_batch=max_batch, compact_latent=True)
    _warm(probe)
    pruned_us = time_fn(lambda: probe.topk(users, topk)[0], iters=5)
    probe.stop()

    capacity = max_batch / (dense_us / 1e6)
    pruned_capacity = max_batch / (pruned_us / 1e6)
    arrival = 1.3 * capacity          # open loop beyond dense capacity
    assert pruned_capacity > 1.1 * arrival, (
        f"scenario can't converge on this host: max-pruned capacity "
        f"{pruned_capacity:.0f} req/s <= arrival {arrival:.0f} req/s"
    )
    interval = 1.0 / arrival
    n_requests = max(int(arrival * duration_s), 8 * max_batch)
    # budget: generous vs one dense batch, impossible vs an unbounded backlog
    budget_ms = max(6.0 * dense_us / 1e3, 25.0)

    def run(with_controller):
        engine = ServingEngine(params, 0.0, 0.0, use_kernel=False,
                               max_batch=max_batch, compact_latent=True)
        _warm(engine)
        queue = engine.start(linger_ms=1.0,
                             max_pending=max(4096, 2 * n_requests))
        controller = None
        if with_controller:
            controller = SLOController(
                engine,
                config=SLOConfig(
                    p99_budget_ms=budget_ms,
                    max_rate=max_rate,
                    step_up=max_rate,     # shed in ONE step: each distinct
                                          # rate is a swap + layout rebuild,
                                          # so don't creep through several
                    depth_high=2 * max_batch,
                    min_window=8,
                    tick_interval_s=0.05,
                ),
                queue=queue,
            )
        lat, failures = _open_loop(
            engine, n_requests=n_requests, interval_s=interval,
            topk=topk, controller=controller,
        )
        engine.stop()
        steady = lat[n_requests // 2:]
        steady = steady[np.isfinite(steady)]
        p99 = float(np.percentile(steady * 1e3, 99)) if steady.size else float("inf")
        return p99, failures, controller

    base_p99, base_failures, _ = run(with_controller=False)
    ctl_p99, ctl_failures, controller = run(with_controller=True)

    emit("slo/overload_fixed_dense_p99", base_p99 * 1e3,
         f"budget_ms={budget_ms:.1f}")
    emit("slo/overload_controller_p99", ctl_p99 * 1e3,
         f"budget_ms={budget_ms:.1f} "
         f"degrades={controller.degrades} swaps={controller.swaps}")
    print(f"# overload: arrival {arrival:.0f} req/s vs dense capacity "
          f"{capacity:.0f} req/s (max-pruned {pruned_capacity:.0f}); "
          f"fixed p99 {base_p99:.1f} ms, controller p99 {ctl_p99:.1f} ms "
          f"(budget {budget_ms:.1f} ms)")

    assert not ctl_failures, (
        f"controller run dropped/failed requests: {ctl_failures[:3]}"
    )
    assert base_p99 > budget_ms, (
        f"overload not overloading: fixed-threshold p99 {base_p99:.1f} ms "
        f"under budget {budget_ms:.1f} ms"
    )
    assert ctl_p99 <= budget_ms, (
        f"controller failed to hold p99: {ctl_p99:.1f} ms > budget "
        f"{budget_ms:.1f} ms"
    )
    assert controller.degrades > 0 and controller.swaps > 0
    print("# overload OK: controller held p99 under budget, zero drops; "
          "fixed threshold blew it")
    return {
        "arrival_req_s": arrival,
        "dense_capacity_req_s": capacity,
        "pruned_capacity_req_s": pruned_capacity,
        "budget_ms": budget_ms,
        "fixed_dense_p99_ms": base_p99,
        "controller_p99_ms": ctl_p99,
        "fixed_dense_failures": len(base_failures),
        "controller_failures": len(ctl_failures),
        "controller": controller.report(),
    }


def run(*, full: bool = False, smoke: bool = False) -> None:
    """Entry point for ``benchmarks.run``: frontier sweep + overload replay."""
    reset_records()
    if smoke:
        frontier_cfg = dict(m=512, n=30000, k=96, batch=64, topk=10)
        overload_cfg = dict(m=512, n=60000, k=96, topk=10,
                            duration_s=2.0, max_batch=16)
    elif full:
        frontier_cfg = dict(m=4096, n=120000, k=96, batch=256, topk=10)
        overload_cfg = dict(m=1024, n=120000, k=96, topk=10,
                            duration_s=10.0, max_batch=16)
    else:
        frontier_cfg = dict(m=1024, n=60000, k=96, batch=128, topk=10)
        overload_cfg = dict(m=512, n=60000, k=96, topk=10,
                            duration_s=5.0, max_batch=16)

    points = _frontier(rates=(0.0, 0.12, 0.35), **frontier_cfg)
    overload = _overload(**overload_cfg)

    write_json("slo", {
        "frontier": points,
        "overload": overload,
    })


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--full", action="store_true")
    parser.add_argument("--smoke", action="store_true")
    args = parser.parse_args()
    run(full=args.full, smoke=args.smoke)


if __name__ == "__main__":
    main()
