"""Out-of-core data-path benchmarks: the million-user scaling story.

Sweeps the user count upward and trains one epoch per size from an on-disk
ratings store (``src/repro/store``), recording to ``BENCH_scale.json``:

* **bounded host memory** — the anonymous-RSS delta across the streamed
  epoch must stay flat as the dataset grows: the prefetch queue depth, not
  the ratings count, bounds what the training loop keeps resident.  The
  assertion looks at *anonymous* RSS (``/proc/self/smaps_rollup``), because
  the store's mmap'd shard pages are reclaimable page cache the kernel
  drops under pressure — counting them would call a healthy mmap read-path
  a leak;
* **streaming tax** — steps/sec of the prefetched slab path vs the
  all-in-memory device-resident scan (``PackedRatings``) at a size where
  both fit: the streamed path must hold >= ``MIN_THROUGHPUT_RATIO`` of the
  in-memory throughput (best-of-N epochs on both sides, so a noisy shared
  machine measures the pipeline, not the scheduler).
"""
from __future__ import annotations

import gc
import shutil
import tempfile
import time

import jax

from benchmarks.common import (
    anonymous_rss_mb,
    emit,
    peak_rss_mb,
    reset_records,
    write_json,
)
from repro.core.trainer import DPMFTrainer, TrainConfig
from repro.data import synthetic_ratings
from repro.store import build_store

MIN_THROUGHPUT_RATIO = 0.8   # streamed vs in-memory steps/sec floor
FLATNESS_SLACK_MB = 64.0     # allowed anon-RSS delta growth across the sweep
RATINGS_PER_USER = 10


def _cfg(store_dir: str, batch: int, slab_steps: int, k: int) -> TrainConfig:
    return TrainConfig(
        k=k, epochs=4, batch_size=batch, pruning_rate=0.5, seed=0,
        store_dir=store_dir, slab_steps=slab_steps, prefetch_slabs=2,
    )


def _best_epoch_wall(trainer: DPMFTrainer, epochs: int = 3) -> float:
    """Best steady-state epoch seconds (epoch 0 = compile, excluded)."""
    times = []
    for _ in range(epochs):
        start = time.perf_counter()
        trainer.run_epoch()
        times.append(time.perf_counter() - start)
    return min(times)


def run(*, full: bool = False, smoke: bool = False) -> None:
    reset_records()
    if smoke:
        user_sweep, batch, slab_steps, k = [2_000, 8_000], 128, 32, 8
    elif full:
        # the headline sweep: O(10^6) user rows streamed from disk
        user_sweep, batch, slab_steps, k = (
            [100_000, 400_000, 1_000_000], 4096, 64, 32
        )
    else:
        user_sweep, batch, slab_steps, k = [10_000, 40_000, 160_000], 1024, 64, 16

    workdir = tempfile.mkdtemp(prefix="bench_scale_")
    deltas = {}
    streamed_sps = {}
    try:
        for users in user_sweep:
            # ratings rounded to whole slabs so every size compiles the same
            # (slab_steps, batch) scan — the sweep then measures data-path
            # memory, not per-size XLA compilation
            ratings = max(
                batch * slab_steps,
                users * RATINGS_PER_USER // (batch * slab_steps)
                * batch * slab_steps,
            )
            ds = synthetic_ratings(users, max(users // 10, 100), ratings,
                                   seed=0)
            store_dir = f"{workdir}/store_{users}"
            build_store(ds, store_dir, shard_rows=1 << 20)
            del ds
            gc.collect()

            trainer = DPMFTrainer(_cfg(store_dir, batch, slab_steps, k))
            trainer.run_epoch()   # compile + calibrate outside the meter
            gc.collect()
            anon_before = anonymous_rss_mb()
            wall = _best_epoch_wall(trainer)
            gc.collect()
            anon_after = anonymous_rss_mb()
            steps = trainer._loader.num_steps
            delta = max(0.0, anon_after - anon_before)
            deltas[users] = delta
            streamed_sps[users] = steps / wall
            emit(
                f"scale/streamed/{users}_users",
                wall / steps * 1e6,
                f"steps_per_sec={steps / wall:.1f}"
                f";anon_rss_delta_mb={delta:.1f}"
                f";ratings={ratings}",
            )
            del trainer
            gc.collect()
            shutil.rmtree(store_dir, ignore_errors=True)

        # streaming tax vs the all-in-memory scan at the smallest size
        users = user_sweep[0]
        ratings = max(
            batch * slab_steps,
            users * RATINGS_PER_USER // (batch * slab_steps)
            * batch * slab_steps,
        )
        ds = synthetic_ratings(users, max(users // 10, 100), ratings, seed=0)
        store_dir = f"{workdir}/store_mem"
        build_store(ds, store_dir, shard_rows=1 << 20)
        mem_cfg = TrainConfig(k=k, epochs=4, batch_size=batch,
                              pruning_rate=0.5, seed=0)
        mem_trainer = DPMFTrainer(mem_cfg, ds)
        mem_trainer.run_epoch()
        mem_wall = _best_epoch_wall(mem_trainer)
        mem_steps = mem_trainer._packed_train.num_steps
        mem_sps = mem_steps / mem_wall
        ratio = streamed_sps[users] / mem_sps
        emit(
            "scale/in_memory_baseline",
            mem_wall / mem_steps * 1e6,
            f"steps_per_sec={mem_sps:.1f}",
        )
        emit(
            "scale/streaming_throughput_ratio",
            0.0,
            f"ratio={ratio:.3f};floor={MIN_THROUGHPUT_RATIO}",
        )

        flat_growth = deltas[user_sweep[-1]] - deltas[user_sweep[0]]
        emit(
            "scale/anon_rss_flatness",
            0.0,
            f"growth_mb={flat_growth:.1f};slack_mb={FLATNESS_SLACK_MB}",
        )
        write_json("scale", {
            "config": {"user_sweep": user_sweep, "batch_size": batch,
                       "slab_steps": slab_steps, "k": k,
                       "ratings_per_user": RATINGS_PER_USER},
            "streamed_steps_per_sec": {
                str(u): s for u, s in streamed_sps.items()
            },
            "anon_rss_delta_mb": {str(u): d for u, d in deltas.items()},
            "anon_rss_growth_mb": flat_growth,
            "in_memory_steps_per_sec": mem_sps,
            "streaming_throughput_ratio": ratio,
            "throughput_floor": MIN_THROUGHPUT_RATIO,
            "flatness_slack_mb": FLATNESS_SLACK_MB,
            "peak_rss_mb": peak_rss_mb(),
        })
        assert flat_growth <= FLATNESS_SLACK_MB, (
            f"streamed-epoch anon RSS grew {flat_growth:.1f} MB from "
            f"{user_sweep[0]} to {user_sweep[-1]} users — the prefetch "
            f"queue no longer bounds host memory"
        )
        assert ratio >= MIN_THROUGHPUT_RATIO, (
            f"streamed training holds only {ratio:.2f}x of the in-memory "
            f"scan throughput (floor {MIN_THROUGHPUT_RATIO}x)"
        )
    finally:
        shutil.rmtree(workdir, ignore_errors=True)
        jax.clear_caches()
