"""Workload subsystem: implicit/BPR training cost + ranking-parity guard.

    PYTHONPATH=src python -m benchmarks.bench_workloads [--full]

Three claims, checked then timed:

1. **objective parity is exact** — an implicit-trained model served dense
   (thresholds 0) through ``ServingEngine.topk`` scores the same ranking
   metrics as the brute-force oracle *exactly*, so the pruned-vs-dense gap
   reported below is pruning, never workload plumbing (asserted);
2. **what the weighted objectives cost** — examples/s of the explicit,
   confidence-weighted implicit (positives + sampled negatives through the
   same fused update) and BPR pairwise epoch scans on one shape, so the
   overhead of the richer objectives is a tracked number rather than
   folklore;
3. **prequential ranking is cheap enough to run in-line** — events/s of
   rank-score-then-learn on a rating-free click stream
   (``PrequentialRankingEvaluator`` + WALS conversion) vs the same updates
   without scoring: the cost of knowing your live hit-rate.

Emits the ``name,us_per_call,derived`` CSV contract and writes
``BENCH_workloads.json`` (schema-validated by ``benchmarks/run.py
--smoke``).
"""
from __future__ import annotations

import argparse
import functools
import time

import numpy as np

from benchmarks.common import emit, reset_records, write_json
from repro.core.trainer import DPMFTrainer, TrainConfig
from repro.data import synthetic_ratings, train_test_split
from repro.eval import PrequentialRankingEvaluator
from repro.eval import ranking as ranking_eval
from repro.online import OnlineUpdater, ReplaySource, iter_microbatches
from repro.serving import ServingEngine
from repro.workloads import implicit_event_batch, strip_ratings


def _timed_train(config: TrainConfig, train_ds, test_ds):
    """Train and return (trainer, examples/s over the epoch loop)."""
    trainer = DPMFTrainer(config, train_ds, test_ds)
    start = time.perf_counter()
    trainer.run()
    elapsed = time.perf_counter() - start
    if config.objective == "bpr":
        per_epoch = len(train_ds)          # one sampled triple per rating
    else:
        per_epoch = len(trainer.train_ds)  # implicit: positives + negatives
    return trainer, per_epoch * config.epochs / elapsed


def run(*, full: bool = False, smoke: bool = False) -> None:
    reset_records()
    if smoke:
        m, n, k, ratings = 300, 2000, 16, 6000
        epochs, rate, stream_events = 2, 0.3, 384
    elif full:
        m, n, k, ratings = 8000, 60000, 48, 300000
        epochs, rate, stream_events = 4, 0.3, 4096
    else:
        m, n, k, ratings = 1500, 12000, 32, 50000
        epochs, rate, stream_events = 3, 0.3, 2048

    topk, alpha, negatives = 10, 8.0, 2
    ds = synthetic_ratings(num_users=m, num_items=n, num_ratings=ratings,
                           seed=0)
    rest, stream_ds = train_test_split(ds, 0.25, seed=1)
    train_ds, test_ds = train_test_split(rest, 0.2, seed=2)
    base = dict(k=k, epochs=epochs, batch_size=2048, lr=0.02, lam=0.02,
                pruning_rate=rate, ranking_topk=topk, seed=0)

    # ---- 1. objective training throughput ----------------------------------
    _, explicit_s = _timed_train(TrainConfig(**base), train_ds, test_ds)
    implicit_cfg = TrainConfig(objective="implicit", implicit_alpha=alpha,
                               implicit_negatives=negatives, **base)
    implicit_trainer, implicit_s = _timed_train(implicit_cfg, train_ds,
                                                test_ds)
    _, bpr_s = _timed_train(TrainConfig(objective="bpr", **base),
                            train_ds, test_ds)
    for name, rate_s in (("explicit", explicit_s), ("implicit", implicit_s),
                         ("bpr", bpr_s)):
        emit(f"workloads_train_{name}_r{ratings}_k{k}", 1e6 / rate_s,
             f"{rate_s:.0f} examples/s")
    print(f"# training: explicit {explicit_s:.0f} ex/s, implicit "
          f"{implicit_s:.0f} ex/s ({1 + negatives}x data), BPR "
          f"{bpr_s:.0f} triples/s")

    # ---- 2. parity at t=0, then the pruned-vs-dense ranking gap ------------
    params = implicit_trainer.params
    t_p, t_q = implicit_trainer.t_p, implicit_trainer.t_q
    holdout = implicit_trainer.test_ds   # binarized positives
    dense_engine = ServingEngine(params, 0.0, 0.0, use_kernel=False,
                                 max_batch=256)
    oracle = ranking_eval.evaluate_oracle(params, holdout, topk)
    engine_dense = ranking_eval.evaluate_engine(dense_engine, holdout, topk)
    assert engine_dense == oracle, (
        f"implicit engine/oracle divergence at t=0: {engine_dense} vs "
        f"{oracle}"
    )
    print(f"# parity at t=0: implicit-trained engine == oracle exactly "
          f"(NDCG@{topk} {oracle.ndcg:.4f}, {oracle.users} users)")

    pruned_engine = ServingEngine(params, t_p, t_q, use_kernel=False,
                                  max_batch=256)
    pruned = ranking_eval.evaluate_engine(pruned_engine, holdout, topk)
    gap = oracle.ndcg - pruned.ndcg
    emit(f"workloads_implicit_gap_ndcg{topk}_rate{rate}", abs(gap) * 1e6,
         f"dense {oracle.ndcg:.4f} vs pruned {pruned.ndcg:.4f}")
    print(f"# implicit pruned vs dense @ rate {rate}: NDCG {pruned.ndcg:.4f} "
          f"vs {oracle.ndcg:.4f} (gap {gap:+.4f})")

    # ---- 3. prequential-ranking overhead on a click stream -----------------
    def click_batches():
        return iter_microbatches(
            strip_ratings(
                ReplaySource(stream_ds, epochs=None, shuffle=True, seed=3)
            ),
            128, max_events=stream_events,
        )

    to_wals = functools.partial(
        implicit_event_batch, num_items=n, alpha=alpha, negatives=negatives,
        rng=np.random.default_rng(7),
    )

    upd = OnlineUpdater(params, t_p=t_p, t_q=t_q, batch_size=128, seed=5)
    batches = iter(click_batches())
    upd.apply(to_wals(next(batches)))   # compile outside the timed region
    start = time.perf_counter()
    done = 0
    for batch in batches:
        done += len(batch)
        upd.apply(to_wals(batch))
    plain_s = time.perf_counter() - start

    upd2 = OnlineUpdater(params, t_p=t_p, t_q=t_q, batch_size=128, seed=5)
    evaluator = PrequentialRankingEvaluator(upd2, topk=topk,
                                            update_fn=to_wals)
    batches = iter(click_batches())
    evaluator.consume(next(batches))
    start = time.perf_counter()
    for batch in batches:
        evaluator.consume(batch)
    preq_s = time.perf_counter() - start
    overhead = preq_s / max(plain_s, 1e-9) - 1.0
    stats = evaluator.stats
    emit(f"workloads_prequential_rank_b128_n{n}",
         preq_s / max(done, 1) * 1e6,
         f"{done / preq_s:.0f} events/s, {overhead * 100:.0f}% over "
         f"update-only")
    print(f"# prequential ranking: {done / preq_s:.0f} events/s scored+"
          f"applied ({overhead * 100:.0f}% overhead); HR@{topk} "
          f"{stats.hit_rate:.4f} over {stats.events} events "
          f"(new {stats.cohorts['new']['events']}, established "
          f"{stats.cohorts['established']['events']})")

    write_json("workloads", {
        "shape": {"users": m, "items": n, "k": k, "ratings": ratings,
                  "topk": topk, "pruning_rate": rate,
                  "implicit_alpha": alpha,
                  "implicit_negatives": negatives},
        "train_examples_per_s": {"explicit": explicit_s,
                                 "implicit": implicit_s, "bpr": bpr_s},
        "parity_at_zero": engine_dense == oracle,
        "dense": oracle.as_dict(),
        "pruned": pruned.as_dict(),
        "gap_ndcg": gap,
        "prequential_events_per_s": done / preq_s,
        "prequential_overhead_frac": overhead,
        "prequential_hit_rate": stats.hit_rate,
    })


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--full", action="store_true",
                        help="catalog-scale shape (slower)")
    args = parser.parse_args()
    run(full=args.full)


if __name__ == "__main__":
    main()
