"""Chaos: replica kill under load, corrupt deltas, supervised recovery.

    PYTHONPATH=src python -m benchmarks.bench_chaos [--full]

Three claims, checked then timed, all driven by the deterministic fault
harness (``repro.testing.faults``) so every run replays the same failure
schedule:

1. **a replica kill loses no requests** — concurrent traffic through a
   local fleet while a seeded :class:`FaultPlan` kills one replica
   mid-stream and a :class:`FleetSupervisor` detects, respawns from a
   healthy peer, and readmits it after convergence.  Asserted: zero
   failed/stranded futures, the death is detected *and* recovered, and
   completed-request throughput during the kill→readmit window stays
   ≥ 90 % of the pre-kill rate (the availability floor).  MTTR
   (detection → readmission) is recorded.
2. **corrupt deltas heal to bitwise convergence** — live replication
   with deliveries corrupted and dropped on the wire: the CRC check
   NAKs the corrupt delta (stale ack), the publisher's lag check forces
   a ``kind=full`` heal, and the surviving fleet must end bitwise equal
   to a fault-free shadow replica fed the same messages.
3. **the harness is free when disarmed** — the per-seam disabled cost
   (one module-attribute check) is timed in nanoseconds.

Emits the ``name,us_per_call,derived`` CSV contract and writes
``BENCH_chaos.json`` (summary schema documented in
``docs/architecture.md``).
"""
from __future__ import annotations

import argparse
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import jax

from benchmarks.common import emit, reset_records, write_json
from repro.core import mf
from repro.online import OnlineUpdater, PoissonSource, SnapshotPublisher, iter_microbatches
from repro.serving.fleet import FleetSupervisor, ServingFleet
from repro.serving.fleet.replica import LocalReplica
from repro.testing import faults
from repro.testing.faults import FaultAction, FaultPlan


def _drive_timed(frontend, users, topk, clients=8, timeout=60.0):
    """Submit every user id through ``clients`` threads; returns
    (completion_monotonic_times, failures)."""
    done_at = []
    failures = []

    def one(u):
        try:
            frontend.submit(int(u), topk, timeout=timeout).result(timeout)
            done_at.append(time.monotonic())
        except Exception as exc:  # noqa: BLE001 - any failure is a loss
            failures.append(repr(exc))

    with ThreadPoolExecutor(max_workers=clients) as pool:
        list(pool.map(one, users))
    return done_at, failures


def run(*, full: bool = False, smoke: bool = False) -> None:
    """Run the chaos suite at smoke/default/full scale."""
    reset_records()
    if smoke:
        m, n, k = 400, 3000, 16
        n_requests, replicas = 1500, 3
        stream_batches = 4
    elif full:
        m, n, k = 8000, 60000, 32
        n_requests, replicas = 6000, 4
        stream_batches = 10
    else:
        m, n, k = 2000, 20000, 24
        n_requests, replicas = 3000, 3
        stream_batches = 6
    topk = 10
    kill_at = n_requests // (replicas * 10)  # ~10% into r0's share
    rng = np.random.default_rng(0)
    summary = {"replicas": replicas, "kill_at": kill_at}

    # ---- 1. replica kill under load: zero losses, MTTR, availability -------
    params = mf.init_params(jax.random.PRNGKey(0), m, n, k, variant="bias",
                            global_mean=3.5)
    fleet = ServingFleet(params, 0.0, 0.0, replicas=replicas, backend="local",
                         queue_kwargs={"linger_ms": 0.5})
    supervisor = FleetSupervisor(
        fleet.router, probe_interval_s=0.02, ping_timeout_s=2.0, dead_after=1,
    )
    supervisor.start()
    plan = FaultPlan([
        FaultAction(site="replica.submit", op="kill", at=kill_at, target="r0"),
    ])
    users = rng.integers(0, m, n_requests)
    t_start = time.monotonic()
    with faults.installed(plan):
        done_at, failures = _drive_timed(fleet, users, topk)
        # keep probing until the respawn lands (traffic may finish first)
        deadline = time.monotonic() + 60.0
        while time.monotonic() < deadline:
            rep = supervisor.report()
            if rep["deaths"] and rep["recovered"] == rep["deaths"]:
                break
            time.sleep(0.01)
    supervisor.stop()
    rep = supervisor.report()
    stats = fleet.stats()
    fleet.close()
    assert not failures, f"replica kill lost requests: {failures[:3]}"
    assert plan.pending == 0, "the scheduled kill never fired"
    assert rep["deaths"] >= 1, "supervisor never detected the kill"
    assert rep["recovered"] == rep["deaths"], f"unrecovered incident: {rep}"
    incident = supervisor.incidents[0]
    mttr_s = rep["mttr_max_s"]
    # availability: completed-request rate during the incident window vs
    # before the kill.  The window is stretched to ≥100 ms so a fast respawn
    # still yields a statistically meaningful rate.
    det, healed = incident.detected_at, incident.healthy_at
    window_end = max(healed, det + 0.1)
    pre = sum(1 for t in done_at if t < det)
    dur = sum(1 for t in done_at if det <= t <= window_end)
    pre_rate = pre / max(det - t_start, 1e-9)
    dur_rate = dur / max(window_end - det, 1e-9)
    availability = min(1.0, dur_rate / max(pre_rate, 1e-9))
    assert availability >= 0.9, (
        f"availability during kill→respawn {availability:.3f} < 0.9 "
        f"({dur_rate:.0f} vs {pre_rate:.0f} req/s)"
    )
    wall = max(done_at) - t_start
    emit("chaos_kill_req_s", len(done_at) / wall,
         f"failovers={stats['failovers']} repins={stats['affinity_repins']}")
    emit("chaos_mttr_ms", mttr_s * 1e3,
         f"probes={rep['probes']} respawns={rep['respawns']}")
    emit("chaos_availability", availability,
         f"during={dur_rate:.0f}req_s before={pre_rate:.0f}req_s")
    summary.update({
        "lost_futures": len(failures),
        "zero_lost_futures": True,
        "deaths": rep["deaths"],
        "recovered": rep["recovered"],
        "failovers": int(stats["failovers"]),
        "mttr_s": round(mttr_s, 4),
        "availability_during_incident": round(availability, 4),
    })

    # ---- 2. corrupt/dropped deltas: NAK -> full heal -> bitwise equal ------
    upd = OnlineUpdater(params, None, 0.0, 0.0, batch_size=256, seed=7)
    fleet = ServingFleet(params, 0.0, 0.0, replicas=replicas, backend="local",
                         queue_kwargs={"linger_ms": 0.5})
    shadow = LocalReplica("shadow", params, 0.0, 0.0)
    pub = SnapshotPublisher(None, upd, compress=True)
    pub.subscribe(fleet.router)
    pub.subscribe(shadow)  # fault-free reference fed the same messages
    plan = FaultPlan([
        FaultAction(site="bus.deliver", op="corrupt", at=1, target="r1"),
        FaultAction(site="bus.deliver", op="drop", at=2, target="r2"),
    ])
    src = PoissonSource(m, n, rate=1e4, seed=7)
    swaps = []
    with faults.installed(plan):
        for batch in iter_microbatches(src, 256, max_events=256 * stream_batches):
            upd.apply(batch)
            swaps.append(pub.publish())
    # one clean publish after the faults: the stale acks left by the corrupt
    # and dropped deliveries force this one out kind=full — the heal
    upd.apply(next(iter_microbatches(PoissonSource(m, n, rate=1e4, seed=8),
                                     256, max_events=256)))
    swaps.append(pub.publish())
    stats = fleet.stats()
    corrupt_dropped = sum(
        r.get("updates_corrupt", 0) for r in stats["replicas"]
    )
    heals = sum(1 for s in swaps if s.kind == "full")
    versions = [r.version for r in fleet.replicas]
    assert corrupt_dropped >= 1, "the CRC check never NAKed the corruption"
    assert all(v == pub.version for v in versions), (
        f"fleet diverged after heal: {versions} != v{pub.version}"
    )
    assert shadow.version == pub.version
    mismatched = []
    shadow_leaves = jax.tree_util.tree_leaves(shadow.engine.params)
    for r in fleet.replicas:
        for a, b in zip(jax.tree_util.tree_leaves(r.engine.params),
                        shadow_leaves):
            if not np.array_equal(np.asarray(a), np.asarray(b)):
                mismatched.append(r.replica_id)
                break
    fleet.close()
    shadow.close()
    assert not mismatched, (
        f"replicas not bitwise-equal to fault-free shadow: {mismatched}"
    )
    emit("chaos_heal_publishes", len(swaps),
         f"full={heals} corrupt_NAKed={corrupt_dropped}")
    summary.update({
        "publishes": len(swaps),
        "heals_kind_full": heals,
        "corrupt_dropped": int(corrupt_dropped),
        "final_version": int(pub.version),
        "bitwise_convergent": True,
    })

    # ---- 3. disarmed-seam cost ---------------------------------------------
    iters = 1_000_000
    t0 = time.perf_counter()
    for _ in range(iters):
        if faults._PLAN is not None:  # the exact production guard
            pass
    seam_ns = (time.perf_counter() - t0) / iters * 1e9
    emit("chaos_seam_off_ns", seam_ns, "per-seam cost with no plan installed")
    summary["seam_off_ns"] = round(seam_ns, 2)

    write_json("chaos", summary)


def main() -> None:
    """CLI entry point."""
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--full", action="store_true")
    parser.add_argument("--smoke", action="store_true")
    args = parser.parse_args()
    run(full=args.full, smoke=args.smoke)


if __name__ == "__main__":
    main()
