"""Kernel-level benchmarks: tile-skip effectiveness of the pruned matmul and
wall-clock of the fused-SGD step vs its unfused XLA form.

On this CPU container, Pallas runs in interpret mode (Python-speed), so
kernel *wall-clock* is not meaningful; the hardware-transferable numbers are
the K-block skip fractions (what `pl.when` elides on a TPU) — reported for
rearranged vs shuffled latent orders, plus the rank-sorted batching variant
(the beyond-paper optimization from §Perf iteration 2).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, reset_records, time_fn, write_json
from repro.core.ranks import effective_ranks
from repro.kernels import ops as kops
from repro.kernels import ref


def _rearranged_factors(m, n, k, seed=0):
    """Factors whose significance is concentrated at low latent indices —
    the post-Algorithm-1 layout."""
    rng = np.random.default_rng(seed)
    decay = np.exp(-np.arange(k) / (k / 6.0))
    p = (rng.normal(0, 0.1, (m, k)) * decay).astype(np.float32)
    q = (rng.normal(0, 0.1, (n, k)) * decay).astype(np.float32)
    return jnp.asarray(p), jnp.asarray(q)


def tile_skip_fractions(m: int = 4096, k: int = 256) -> None:
    n = m
    t = 0.05
    p, q = _rearranged_factors(m, n, k)
    r_u = effective_ranks(p, t)
    r_i = effective_ranks(q, t)

    for bm, bn, bk in ((128, 128, 128), (128, 128, 32), (256, 256, 32)):
        tile, elem = kops.tile_block_stats(
            r_u, r_i, k, block_m=bm, block_n=bn, block_k=bk
        )
        emit(
            f"kernel/tile_skip/b{bm}x{bn}x{bk}",
            0.0,
            f"computed_fraction={float(tile):.3f};elementwise={float(elem):.3f}"
            f";speedup_bound={1.0 / max(float(tile), 1e-9):.2f}x",
        )

    # beyond-paper: sort rows/cols by effective rank before tiling
    order_u = jnp.argsort(r_u)
    order_i = jnp.argsort(r_i)
    tile_s, elem_s = kops.tile_block_stats(
        r_u[order_u], r_i[order_i], k, block_m=128, block_n=128, block_k=32
    )
    emit(
        "kernel/tile_skip/rank_sorted_b128x128x32",
        0.0,
        f"computed_fraction={float(tile_s):.3f};elementwise={float(elem_s):.3f}",
    )

    # shuffled latent order (no Algorithm 1) for contrast
    perm = jax.random.permutation(jax.random.PRNGKey(0), k)
    r_u_s = effective_ranks(p[:, perm], t)
    r_i_s = effective_ranks(q[:, perm], t)
    tile_x, _ = kops.tile_block_stats(
        r_u_s, r_i_s, k, block_m=128, block_n=128, block_k=32
    )
    emit(
        "kernel/tile_skip/shuffled_b128x128x32",
        0.0,
        f"computed_fraction={float(tile_x):.3f}",
    )


def fused_sgd_wallclock(b: int = 65536, k: int = 128) -> None:
    """Fusion benefit measured at the XLA level (masked ops): fused ref vs
    three separate passes over the row blocks."""
    rng = np.random.default_rng(0)
    p = jnp.asarray(rng.normal(0, 0.1, (b, k)).astype(np.float32))
    q = jnp.asarray(rng.normal(0, 0.1, (b, k)).astype(np.float32))
    r = jnp.asarray(rng.uniform(1, 5, b).astype(np.float32))
    t = jnp.float32(0.06)

    fused = jax.jit(
        lambda p, q, r: ref.fused_mf_sgd_ref(p, q, r, t, t, lr=0.05, lam=0.02)
    )

    @jax.jit
    def unfused(p, q, r):
        from repro.core.ranks import effective_ranks, rank_mask

        r_u = effective_ranks(p, t)
        r_i = effective_ranks(q, t)
        mask = rank_mask(jnp.minimum(r_u, r_i), k)
        pred = jnp.sum(p * q * mask, axis=-1)          # pass 1
        err = r - pred
        new_p = p + 0.05 * (err[:, None] * q - 0.02 * p) * mask  # pass 2
        new_q = q + 0.05 * (err[:, None] * p - 0.02 * q) * mask  # pass 3
        return new_p, new_q, err

    t_fused = time_fn(fused, p, q, r)
    t_unfused = time_fn(unfused, p, q, r)
    emit("kernel/fused_sgd_xla", t_fused, f"unfused_us={t_unfused:.1f}")

    dense_mm = jax.jit(lambda a, c: a @ c.T)
    masked_mm = jax.jit(
        lambda a, c: ref.pruned_matmul_ref(
            a, c, effective_ranks(a, 0.06), effective_ranks(c, 0.06)
        )
    )
    a = p[:2048]
    c = q[:2048]
    emit(
        "kernel/matmul_dense_xla",
        time_fn(dense_mm, a, c),
        f"masked_us={time_fn(masked_mm, a, c):.1f}",
    )


def kernel_interpret_correctness() -> None:
    """One interpret-mode execution of each Pallas kernel (correctness is
    tested extensively in tests/test_kernels.py; this records that the lowered
    kernels run)."""
    p, q = _rearranged_factors(256, 256, 128, seed=1)
    out = kops.pruned_matmul(p, q, 0.05, 0.05)
    r_u = effective_ranks(p, 0.05)
    r_i = effective_ranks(q, 0.05)
    expected = ref.pruned_matmul_ref(p, q, r_u, r_i)
    err = float(jnp.max(jnp.abs(out - expected)))
    emit("kernel/pallas_pruned_matmul_interpret", 0.0, f"max_err={err:.2e}")


def run(*, full: bool = False, smoke: bool = False) -> None:
    del full
    reset_records()
    if smoke:
        tile_skip_fractions(m=512, k=256)
        fused_sgd_wallclock(b=2048, k=64)
    else:
        tile_skip_fractions()
        fused_sgd_wallclock()
    kernel_interpret_correctness()
    write_json("kernels")
