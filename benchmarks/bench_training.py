"""Training-path benchmarks: the paper's 1.2-1.65x *training* speedup claim
made measurable.

Two axes are reported to ``BENCH_training.json``:

* **dispatch efficiency** — steps/sec of the legacy per-batch Python loop
  (`epoch_mode="python"`: one `train_step` dispatch + host upload per batch)
  vs the epoch-compiled path (`epoch_mode="scan"`: on-device reshuffle + one
  donated `lax.scan` per epoch).  On CPU-sized batches dispatch dominates
  MACs, so this is where wall-clock actually goes;
* **work-proportional speedup** — executed MACs vs dense (the paper's own
  metric, hardware-independent), compared against the paper's 1.2-1.65x
  band.

The fused rows route the update through the Pallas kernel (interpret mode on
CPU — the XLA-lowered kernel body, so the numbers transfer in shape, not in
absolute microseconds).
"""
from __future__ import annotations

import time

from benchmarks.common import emit, reset_records, write_json
from repro.core.trainer import DPMFTrainer, TrainConfig
from repro.data import synthetic_ratings

PAPER_BAND = (1.2, 1.65)
MIN_SCAN_SPEEDUP = 3.0  # acceptance floor on the CPU CI config


def _time_epochs(trainer: DPMFTrainer, epochs: int = 3) -> float:
    """Best steady-state epoch wall seconds (epoch 0 = compile + calibrate,
    excluded; min is the stable estimator on a shared/noisy machine)."""
    times = []
    for _ in range(epochs):
        start = time.perf_counter()
        trainer.run_epoch()
        times.append(time.perf_counter() - start)
    return min(times)


def run(*, full: bool = False, smoke: bool = False) -> None:
    reset_records()
    # The non-full shapes keep per-step compute small so the number measures
    # what the scan path removes — per-batch dispatch/upload/sync overhead —
    # the regime CPU CI (and any small-batch trainer) actually sits in.
    if smoke:
        m, n, ratings, k, batch = 300, 400, 12_000, 16, 64
    elif full:
        m, n, ratings, k, batch = 6000, 4000, 1_000_000, 64, 4096
    else:
        m, n, ratings, k, batch = 400, 600, 60_000, 16, 128
    rate = 0.5
    ds = synthetic_ratings(m, n, ratings, seed=0)
    steps = len(ds) // batch

    def cfg(**kw):
        base = dict(
            k=k, epochs=16, batch_size=batch, pruning_rate=rate,
            optimizer="adagrad", seed=0,
        )
        base.update(kw)
        return TrainConfig(**base)

    variants = [
        ("python_loop/dense", cfg(epoch_mode="python", pruning_rate=0.0)),
        ("python_loop/pruned", cfg(epoch_mode="python")),
        ("python_loop/fused", cfg(epoch_mode="python", optimizer="sgd",
                                  lr=0.005, use_fused_kernel=True)),
        ("scan/dense", cfg(epoch_mode="scan", pruning_rate=0.0)),
        ("scan/pruned", cfg(epoch_mode="scan")),
        ("scan/fused", cfg(epoch_mode="scan", optimizer="sgd",
                           lr=0.005, use_fused_kernel=True)),
    ]

    results = {}
    for name, config in variants:
        trainer = DPMFTrainer(config, ds, None)  # no test set: train path only
        trainer.run_epoch()  # compile + (for pruned) threshold calibration
        wall = _time_epochs(trainer)
        record = trainer.history[-1]
        results[name] = {
            "steps_per_sec": steps / wall,
            "epoch_wall_s": wall,
            "work_fraction": record.work_fraction,
        }
        emit(
            f"training/{name}",
            wall / steps * 1e6,
            f"steps_per_sec={steps / wall:.1f}"
            f";epoch_wall_s={wall:.3f}"
            f";work_fraction={record.work_fraction:.3f}",
        )

    scan_speedup = (
        results["scan/pruned"]["steps_per_sec"]
        / results["python_loop/pruned"]["steps_per_sec"]
    )
    work_speedup = 1.0 / max(results["scan/pruned"]["work_fraction"], 1e-9)
    emit(
        "training/scan_vs_python_loop",
        0.0,
        f"speedup={scan_speedup:.2f}x;floor={MIN_SCAN_SPEEDUP}x",
    )
    emit(
        "training/work_speedup_pruned",
        0.0,
        f"speedup={work_speedup:.2f}x"
        f";paper_band={PAPER_BAND[0]}-{PAPER_BAND[1]}x",
    )
    write_json("training", {
        "config": {"users": m, "items": n, "ratings": ratings, "k": k,
                   "batch_size": batch, "steps_per_epoch": steps,
                   "pruning_rate": rate},
        "steps_per_sec": {
            name: r["steps_per_sec"] for name, r in results.items()
        },
        "epoch_wall_s": {
            name: r["epoch_wall_s"] for name, r in results.items()
        },
        "scan_speedup_vs_python_loop": scan_speedup,
        "work_speedup_pruned": work_speedup,
        "paper_speedup_band": list(PAPER_BAND),
    })
    assert scan_speedup >= MIN_SCAN_SPEEDUP, (
        f"epoch-compiled path regressed: {scan_speedup:.2f}x < "
        f"{MIN_SCAN_SPEEDUP}x over the per-batch Python loop"
    )
