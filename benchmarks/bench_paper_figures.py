"""Benchmarks reproducing the paper's tables/figures on synthetic datasets of
matching shape (no network access — see data/ratings.paper_dataset).

  fig2  — proportion of total time spent in the MF process vs #epochs
  fig5  — per-latent-vector sparsity across epochs (trend holds => one-shot
          rearrangement is valid)
  fig7  — factor distributions are normal-like; Eq. 7/8 threshold hits the
          requested pruning rate empirically
  fig11 — speedup & MAE vs pruning rate (the headline result)
  fig12 — runtime vs k (dense vs accelerated)
  fig13 — hyperparameter sweeps (lr / strategy / init)

Speedups are reported two ways (DESIGN.md §6): `work` speedup = dense MACs /
executed MACs (hardware-transferable; compare with the paper's 1.2-1.65x),
and `wall` = CPU wall-clock ratio (reported for completeness; a vectorized
masked CPU run does not skip masked FLOPs).
"""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, reset_records, write_json
from repro.core import (
    DPMFTrainer,
    TrainConfig,
    percentage_mae,
    sparsity_per_dim,
    work_speedup,
)
from repro.core.threshold import (
    empirical_pruned_fraction,
    measure_stats,
    threshold_for_rate,
)
from repro.data import paper_dataset, train_test_split


def _dataset(name: str, scale: float, seed: int = 0):
    ds = paper_dataset(name, seed=seed, scale=scale)
    return train_test_split(ds, 0.2, seed=seed)


def _train(train_ds, test_ds, **kw):
    # Paper protocol: LibMF defaults (adagrad, lr 0.1, non-negative init).
    defaults = dict(k=30, epochs=8, batch_size=4096, optimizer="adagrad",
                    lr=0.1, init_method="libmf", seed=0)
    defaults.update(kw)
    trainer = DPMFTrainer(TrainConfig(**defaults), train_ds, test_ds)
    trainer.run()
    return trainer


def fig2_time_share(scale: float = 0.3) -> None:
    train_ds, test_ds = _dataset("movielens100k", scale)
    for epochs in (1, 5, 10):
        t0 = time.perf_counter()
        trainer = _train(train_ds, None, epochs=epochs)
        total = time.perf_counter() - t0
        mf_time = trainer.total_train_time()
        emit(
            f"fig2/time_share_epochs{epochs}",
            total * 1e6,
            f"mf_fraction={mf_time / total:.3f}",
        )


def fig5_sparsity_trend(scale: float = 0.3) -> None:
    train_ds, test_ds = _dataset("movielens100k", scale)
    trainer = DPMFTrainer(
        TrainConfig(k=30, epochs=6, batch_size=4096, pruning_rate=0.0), train_ds
    )
    threshold = 0.06
    rows = []
    for _ in range(6):
        trainer.run_epoch()
        sp_p = float(jnp.mean(sparsity_per_dim(trainer.params.p, threshold)))
        sp_q = float(jnp.mean(sparsity_per_dim(trainer.params.q, threshold)))
        rows.append((sp_p, sp_q))
    emit(
        "fig5/sparsity_trend",
        0.0,
        "p_sparsity=" + "|".join(f"{a:.3f}" for a, _ in rows)
        + ";q_sparsity=" + "|".join(f"{b:.3f}" for _, b in rows),
    )
    # the paper's observation: sparsity decreases with training
    assert rows[0][0] >= rows[-1][0] - 0.05


def fig7_threshold_accuracy(scale: float = 0.3) -> None:
    train_ds, _ = _dataset("movielens100k", scale)
    trainer = DPMFTrainer(
        TrainConfig(k=30, epochs=1, batch_size=4096, pruning_rate=0.0), train_ds
    )
    trainer.run_epoch()
    for rate in (0.1, 0.3, 0.5):
        stats = measure_stats(trainer.params.p)
        t = threshold_for_rate(stats, rate)
        frac = float(empirical_pruned_fraction(trainer.params.p, t))
        emit(
            f"fig7/threshold_rate{rate}",
            0.0,
            f"T={float(t):.4f};empirical={frac:.3f};target={rate}",
        )


def fig11_speedup_vs_rate(
    datasets=("movielens100k", "jester"), scale: float = 0.25, epochs: int = 25
) -> None:
    for name in datasets:
        train_ds, test_ds = _dataset(name, scale)
        t0 = time.perf_counter()
        dense = _train(train_ds, test_ds, epochs=epochs, pruning_rate=0.0)
        t_dense = time.perf_counter() - t0
        base_mae = dense.history[-1].test_mae
        emit(f"fig11/{name}/rate0.0", t_dense * 1e6, f"mae={base_mae:.4f}")
        for rate in (0.1, 0.3, 0.5):
            t0 = time.perf_counter()
            acc = _train(train_ds, test_ds, epochs=epochs, pruning_rate=rate)
            t_acc = time.perf_counter() - t0
            mae = acc.history[-1].test_mae
            emit(
                f"fig11/{name}/rate{rate}",
                t_acc * 1e6,
                f"mae={mae:.4f};pmae={percentage_mae(mae, base_mae):.2f}%"
                f";work_speedup={work_speedup(acc.history):.3f}"
                f";wall_speedup={t_dense / t_acc:.3f}",
            )


def fig12_runtime_vs_k(scale: float = 0.25, epochs: int = 15) -> None:
    train_ds, test_ds = _dataset("movielens100k", scale)
    for k in (20, 50, 80):
        dense = _train(train_ds, None, k=k, epochs=epochs, pruning_rate=0.0)
        acc = _train(train_ds, None, k=k, epochs=epochs, pruning_rate=0.3)
        emit(
            f"fig12/k{k}",
            dense.total_train_time() * 1e6,
            f"work_speedup={work_speedup(acc.history):.3f}"
            f";acc_wall_us={acc.total_train_time() * 1e6:.0f}",
        )


def fig13_hyperparams(scale: float = 0.25, epochs: int = 15) -> None:
    train_ds, test_ds = _dataset("movielens100k", scale)
    base = _train(train_ds, test_ds, epochs=epochs, pruning_rate=0.0)
    base_mae = base.history[-1].test_mae

    variants = {
        "lr0.05": dict(lr=0.05),
        "lr0.1": dict(lr=0.1),
        "lr0.15": dict(lr=0.15),
        "twin": dict(strategy="twin"),
        "normal_init": dict(init_method="normal"),
    }
    for name, kw in variants.items():
        acc = _train(train_ds, test_ds, epochs=epochs, pruning_rate=0.3, **kw)
        mae = acc.history[-1].test_mae
        emit(
            f"fig13/{name}",
            acc.total_train_time() * 1e6,
            f"work_speedup={work_speedup(acc.history):.3f}"
            f";pmae={percentage_mae(mae, base_mae):.2f}%",
        )


def ablation_rearrangement(scale: float = 0.5, epochs: int = 15) -> None:
    """Beyond-paper ablation: Algorithm 1's role.  The paper argues the
    joint-sparsity rearrangement limits pruning error; removing it (prune
    with the same thresholds, original latent order) should cost accuracy
    and/or skip less coherent work."""
    train_ds, test_ds = _dataset("movielens100k", scale)
    dense = _train(train_ds, test_ds, epochs=epochs, pruning_rate=0.0)
    base_mae = dense.history[-1].test_mae
    with_r = _train(train_ds, test_ds, epochs=epochs, pruning_rate=0.3)
    without_r = _train(train_ds, test_ds, epochs=epochs, pruning_rate=0.3,
                       rearrange=False)
    for name, t in (("with_alg1", with_r), ("without_alg1", without_r)):
        emit(
            f"ablation/rearrangement/{name}",
            t.total_train_time() * 1e6,
            f"pmae={percentage_mae(t.history[-1].test_mae, base_mae):.2f}%"
            f";work_speedup={work_speedup(t.history):.3f}",
        )


def run(*, full: bool = False, smoke: bool = False) -> None:
    reset_records()
    if smoke:
        # Toy sizes: exercises every figure path + the report schema fast.
        fig2_time_share(scale=0.05)
        fig5_sparsity_trend(scale=0.05)
        fig7_threshold_accuracy(scale=0.05)
        fig11_speedup_vs_rate(datasets=("movielens100k",), scale=0.05,
                              epochs=3)
        fig12_runtime_vs_k(scale=0.05, epochs=3)
        fig13_hyperparams(scale=0.05, epochs=3)
        ablation_rearrangement(scale=0.05, epochs=3)
    else:
        scale = 1.0 if full else 0.25
        fig2_time_share(scale=min(scale, 0.3))
        fig5_sparsity_trend(scale=min(scale, 0.3))
        fig7_threshold_accuracy(scale=min(scale, 0.3))
        fig11_speedup_vs_rate(scale=(1.0 if full else 0.5), epochs=25)
        fig12_runtime_vs_k(scale=scale)
        fig13_hyperparams(scale=scale)
        ablation_rearrangement(scale=0.5)
    write_json("figures")
