"""Documentation gates: docstring coverage + intra-repo link integrity.

Two checks, both dependency-free (stdlib ``ast`` + ``re``) so they run in
any environment the test suite runs in — the same gates the CI ``docs`` job
enforces:

* **docstring coverage** (interrogate-style): every module, public class,
  and public function/method under the given source trees should carry a
  docstring; the gate fails below ``--min-coverage`` percent.  Private
  names (leading ``_``, dunders included) and nested defs are exempt —
  the gate is about the *public API surface*.

      python tools/check_docs.py --min-coverage 80 \
          src/repro/serving src/repro/online src/repro/eval

* **link integrity**: every relative ``[text](path)`` markdown link in the
  given files/directories must resolve to an existing file in the repo
  (anchors are stripped; absolute URLs are ignored).

      python tools/check_docs.py --links README.md ROADMAP.md docs

Both can run in one invocation; exit status is non-zero if either fails.
"""
from __future__ import annotations

import argparse
import ast
import os
import re
import sys
from typing import Iterator, List, Tuple


# ---------------------------------------------------------------------------
# docstring coverage
# ---------------------------------------------------------------------------


def _python_files(paths: List[str]) -> Iterator[str]:
    """Yield .py files under each path (files pass through as-is)."""
    for path in paths:
        if os.path.isfile(path):
            yield path
            continue
        for root, _, names in sorted(os.walk(path)):
            for name in sorted(names):
                if name.endswith(".py"):
                    yield os.path.join(root, name)


def _public_defs(tree: ast.Module) -> Iterator[Tuple[str, ast.AST]]:
    """Walk module/class bodies (not nested functions) yielding the public
    definitions whose docstrings the gate counts."""
    yield "module", tree
    stack = [(None, node) for node in tree.body]
    while stack:
        prefix, node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if not node.name.startswith("_"):
                yield _qual(prefix, node.name), node
        elif isinstance(node, ast.ClassDef):
            if not node.name.startswith("_"):
                yield _qual(prefix, node.name), node
                stack.extend(
                    (_qual(prefix, node.name), child) for child in node.body
                )


def _qual(prefix, name: str) -> str:
    return f"{prefix}.{name}" if prefix else name


def doc_coverage(paths: List[str]) -> Tuple[int, int, List[str]]:
    """Return ``(documented, total, missing)`` over the public definitions
    of every Python file under ``paths``; ``missing`` holds
    ``file:line name`` strings for each undocumented definition."""
    documented = total = 0
    missing: List[str] = []
    for filename in _python_files(paths):
        with open(filename) as f:
            tree = ast.parse(f.read(), filename=filename)
        for name, node in _public_defs(tree):
            total += 1
            if ast.get_docstring(node):
                documented += 1
            else:
                line = getattr(node, "lineno", 1)
                missing.append(f"{filename}:{line} {name}")
    return documented, total, missing


# ---------------------------------------------------------------------------
# markdown link integrity
# ---------------------------------------------------------------------------

_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def _markdown_files(paths: List[str]) -> Iterator[str]:
    for path in paths:
        if os.path.isfile(path):
            yield path
            continue
        for root, _, names in sorted(os.walk(path)):
            for name in sorted(names):
                if name.endswith(".md"):
                    yield os.path.join(root, name)


def check_links(paths: List[str]) -> List[str]:
    """Return ``file: target`` strings for every relative markdown link
    that does not resolve to an existing file or directory."""
    broken: List[str] = []
    for filename in _markdown_files(paths):
        base = os.path.dirname(os.path.abspath(filename))
        with open(filename) as f:
            text = f.read()
        for match in _LINK_RE.finditer(text):
            target = match.group(1)
            if target.startswith(_SKIP_PREFIXES):
                continue
            target = target.split("#", 1)[0]
            if not target:
                continue
            resolved = os.path.normpath(os.path.join(base, target))
            if not os.path.exists(resolved):
                broken.append(f"{filename}: {match.group(1)}")
    return broken


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main() -> int:
    """Run the configured gates; returns the process exit status."""
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("paths", nargs="*",
                        help="source trees for the docstring-coverage gate")
    parser.add_argument("--min-coverage", type=float, default=80.0,
                        help="minimum docstring coverage percent")
    parser.add_argument("--links", nargs="*", default=None, metavar="PATH",
                        help="markdown files/dirs for the link gate")
    args = parser.parse_args()
    failed = False

    if args.paths:
        documented, total, missing = doc_coverage(args.paths)
        pct = 100.0 * documented / max(total, 1)
        print(f"docstring coverage: {documented}/{total} = {pct:.1f}% "
              f"(gate: {args.min_coverage:.0f}%)")
        if pct < args.min_coverage:
            failed = True
            print("undocumented public definitions:")
            for entry in missing:
                print(f"  {entry}")

    if args.links is not None:
        broken = check_links(args.links or ["."])
        if broken:
            failed = True
            print("broken intra-repo markdown links:")
            for entry in broken:
                print(f"  {entry}")
        else:
            print("markdown links: all intra-repo targets resolve")

    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
