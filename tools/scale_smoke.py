"""CI smoke for the out-of-core data path (the `scale-smoke` job).

Three gates, each fatal on failure:

1. **store build + streamed training** — converts a synthetic ratings
   dataset into an on-disk columnar store and trains two epochs from it
   through the bounded-prefetch slab loader;
2. **mid-epoch kill + resume, bitwise** — repeats the run but kills the
   process-equivalent (a ``KeyboardInterrupt`` injected into the slab scan)
   partway through epoch 1, restores from the mid-epoch checkpoint, and
   asserts every parameter/optimizer array AND the logged epoch metrics are
   bitwise identical to the uninterrupted run;
3. **eviction-armed online launcher** — runs ``repro.launch.online`` with
   ``--evict-max-users`` small enough that the poisson new-user stream
   forces live eviction/compaction rounds, and checks the report says so.

Usage:  PYTHONPATH=src python tools/scale_smoke.py
"""
from __future__ import annotations

import json
import os
import shutil
import subprocess
import sys
import tempfile

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro.core.trainer as trainer_lib
from repro.core.trainer import DPMFTrainer, TrainConfig
from repro.data import synthetic_ratings
from repro.store import RatingsStore, build_store


def _cfg(store_dir: str, ckpt_dir: str | None) -> TrainConfig:
    return TrainConfig(
        k=8, epochs=2, batch_size=64, lr=0.05, lam=0.02, pruning_rate=0.5,
        seed=0, store_dir=store_dir, slab_steps=4, prefetch_slabs=2,
        checkpoint_dir=ckpt_dir, checkpoint_every_epochs=1,
        checkpoint_every_slabs=2,
    )


def _train(store_dir: str, ckpt_dir: str | None, *, kill_after: int = 0):
    """Train 2 epochs; if kill_after > 0, raise after that many slab scans."""
    trainer = DPMFTrainer(_cfg(store_dir, ckpt_dir))
    resumed = trainer.maybe_restore()
    if resumed:
        print(f"  resumed at epoch {trainer.epoch} "
              f"slab {trainer._resume_slab}")
    calls = {"n": 0}
    original = trainer_lib.mf.train_epoch_scan

    def counting(*args, **kwargs):
        calls["n"] += 1
        if kill_after and calls["n"] > kill_after:
            raise KeyboardInterrupt("injected mid-epoch kill")
        return original(*args, **kwargs)

    trainer_lib.mf.train_epoch_scan = counting
    try:
        while trainer.epoch < trainer.config.epochs:
            trainer.run_epoch()
    except KeyboardInterrupt:
        print(f"  killed after {kill_after} slab scans")
    finally:
        trainer_lib.mf.train_epoch_scan = original
        if trainer._ckpt is not None:
            trainer._ckpt.wait()
    return trainer


def _assert_bitwise(a: DPMFTrainer, b: DPMFTrainer) -> None:
    pairs = [("params.p", a.params.p, b.params.p),
             ("params.q", a.params.q, b.params.q)]
    for name, x, y in pairs:
        assert np.array_equal(np.asarray(x), np.asarray(y)), (
            f"{name} diverged after resume")
    for group in a.opt_state._fields:
        ga, gb = getattr(a.opt_state, group), getattr(b.opt_state, group)
        if isinstance(ga, dict):
            for key in ga:
                assert np.array_equal(np.asarray(ga[key]),
                                      np.asarray(gb[key])), (
                    f"opt_state.{group}[{key}] diverged after resume")
    ra, rb = a.history[-1], b.history[-1]
    assert ra.train_abs_err == rb.train_abs_err, (
        f"epoch metric diverged: {ra.train_abs_err!r} vs "
        f"{rb.train_abs_err!r}")
    print("  bitwise parity: params, opt_state, epoch metrics all equal")


def main() -> int:
    workdir = tempfile.mkdtemp(prefix="scale_smoke_")
    try:
        # ---- gate 1: build a store and stream-train from it --------------
        print("[1/3] build store + streamed 2-epoch training")
        ds = synthetic_ratings(400, 120, 4096, seed=0)
        store_dir = os.path.join(workdir, "store")
        build_store(ds, store_dir)
        store = RatingsStore(store_dir)
        assert len(store) == len(ds), "store lost ratings"
        baseline = _train(store_dir, None)
        assert len(baseline.history) == 2
        print(f"  mae trajectory: "
              f"{[round(r.test_mae, 4) for r in baseline.history]}")

        # ---- gate 2: kill mid-epoch-1, resume, demand bitwise parity -----
        print("[2/3] mid-epoch kill + resume (bitwise)")
        ckpt_dir = os.path.join(workdir, "ckpt")
        # epoch 0 has num_slabs scans; kill 3 scans into epoch 1, after the
        # slab-2 mid-epoch checkpoint has been written
        num_slabs = baseline._loader.num_slabs
        assert num_slabs >= 4, f"need >=4 slabs for a mid-epoch kill"
        _train(store_dir, ckpt_dir, kill_after=num_slabs + 3)
        resumed = _train(store_dir, ckpt_dir)
        _assert_bitwise(baseline, resumed)

        # ---- gate 3: online launcher with eviction armed -----------------
        print("[3/3] launch.online with cold-row eviction armed")
        report_path = os.path.join(workdir, "online_report.json")
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(
            os.path.dirname(os.path.abspath(__file__)), "..", "src")
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.online",
             "--train-epochs", "2", "--events", "640", "--batch-events", "16",
             "--swap-every", "4", "--source", "poisson",
             "--new-id-prob", "0.5", "--evict-max-users", "60",
             "--json", report_path],
            env=env, capture_output=True, text=True, timeout=900,
        )
        sys.stdout.write(proc.stdout[-2000:])
        if proc.returncode != 0:
            sys.stderr.write(proc.stderr[-4000:])
            print("FAIL: launch.online exited nonzero")
            return 1
        with open(report_path) as f:
            report = json.load(f)
        ev = report.get("eviction")
        assert ev is not None, "report missing eviction section"
        assert ev["rounds"] >= 1, "eviction never triggered — smoke too small"
        assert ev["physical_users"] <= 60, "eviction failed to bound residency"
        print(f"  eviction rounds={ev['rounds']} evicted={ev['evicted_total']}"
              f" live={ev['physical_users']} remap_epoch={ev['remap_epoch']}")
        print("scale-smoke: all gates passed")
        return 0
    finally:
        shutil.rmtree(workdir, ignore_errors=True)


if __name__ == "__main__":
    sys.exit(main())
