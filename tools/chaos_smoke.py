"""CI smoke for fleet fault tolerance (the `chaos-smoke` job).

Two gates over ONE two-process fleet, each fatal on failure:

1. **scripted kill under load** — concurrent traffic while a seeded
   :class:`FaultPlan` SIGKILLs replica r0 at its Nth submit; the
   supervisor must detect the death, respawn the child from a healthy
   peer's ``kind=full`` state, and readmit it after convergence.
   Asserted: zero dropped/stranded requests (the router's failover
   absorbs the death) and MTTR under budget.
2. **corrupt delta → heal → bitwise convergence** — live replication
   with one delivery corrupted on the wire: the child's CRC check NAKs
   it (stale ack), the publisher's lag check forces a ``kind=full``
   heal, and every replica's full served state must end bitwise equal
   to a fault-free in-process shadow fed the same messages.

Usage:  PYTHONPATH=src python tools/chaos_smoke.py
"""
from __future__ import annotations

import os
import sys
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core import mf
from repro.online import OnlineUpdater, PoissonSource, SnapshotPublisher, iter_microbatches
from repro.serving.fleet import FleetSupervisor, ServingFleet, bus
from repro.serving.fleet.replica import LocalReplica
from repro.testing import faults
from repro.testing.faults import FaultAction, FaultPlan

MTTR_BUDGET_S = 150.0  # respawn = process spawn + jax import: generous
M, N, K = 300, 2000, 8
N_REQUESTS, KILL_AT = 400, 20


def _drive(frontend, users, topk=5, clients=4, timeout=120.0):
    failures = []

    def one(u):
        try:
            frontend.submit(int(u), topk, timeout=timeout).result(timeout)
        except Exception as exc:  # noqa: BLE001 - any failure is a drop
            failures.append(repr(exc))

    with ThreadPoolExecutor(max_workers=clients) as pool:
        list(pool.map(one, users))
    return failures


def _leaves(msg: bus.DeltaMessage):
    params, _, _, _ = bus.state_from_message(msg)
    return jax.tree_util.tree_leaves(params)


def main() -> int:
    rng = np.random.default_rng(0)
    params = mf.init_params(jax.random.PRNGKey(0), M, N, K, variant="bias",
                            global_mean=3.5)
    print("[0/2] spawning 2-process fleet")
    fleet = ServingFleet(params, 0.0, 0.0, replicas=2, backend="process",
                         queue_kwargs={"linger_ms": 1.0})
    shadow = LocalReplica("shadow", params, 0.0, 0.0)
    supervisor = FleetSupervisor(
        fleet.router, probe_interval_s=0.05, ping_timeout_s=5.0, dead_after=2,
    )
    supervisor.start()
    try:
        # ---- gate 1: scripted SIGKILL under load -------------------------
        print(f"[1/2] kill r0 at submit #{KILL_AT} under "
              f"{N_REQUESTS}-request load")
        plan = FaultPlan([FaultAction(site="replica.submit", op="kill",
                                      at=KILL_AT, target="r0")])
        users = rng.integers(0, M, N_REQUESTS)
        with faults.installed(plan):
            failures = _drive(fleet, users)
            deadline = time.monotonic() + MTTR_BUDGET_S + 30.0
            while time.monotonic() < deadline:
                rep = supervisor.report()
                if rep["deaths"] and rep["recovered"] == rep["deaths"]:
                    break
                time.sleep(0.2)
        rep = supervisor.report()
        assert plan.pending == 0, "the scheduled kill never fired"
        assert not failures, f"dropped requests: {failures[:3]}"
        assert rep["deaths"] >= 1, "supervisor never detected the kill"
        assert rep["recovered"] == rep["deaths"], f"unrecovered: {rep}"
        assert rep["mttr_max_s"] < MTTR_BUDGET_S, (
            f"MTTR {rep['mttr_max_s']:.1f}s over budget {MTTR_BUDGET_S}s"
        )
        print(f"  zero drops; death detected+respawned, "
              f"MTTR {rep['mttr_max_s']:.2f}s")

        # ---- gate 2: corrupt delta -> NAK -> full heal -> bitwise --------
        print("[2/2] corrupt one delta to r1, demand bitwise heal")
        upd = OnlineUpdater(params, None, 0.0, 0.0, batch_size=128, seed=7)
        pub = SnapshotPublisher(None, upd, compress=True)
        pub.subscribe(fleet.router)
        pub.subscribe(shadow)  # fault-free reference, same messages
        plan = FaultPlan([FaultAction(site="bus.deliver", op="corrupt",
                                      at=1, target="r1")])
        src = PoissonSource(M, N, rate=1e4, seed=7)
        swaps = []
        with faults.installed(plan):
            for batch in iter_microbatches(src, 128, max_events=128 * 3):
                upd.apply(batch)
                swaps.append(pub.publish())
        # clean publish after the faults: the corrupt NAK left r1's ack
        # stale, so the publisher has forced a kind=full heal by now
        upd.apply(next(iter_microbatches(
            PoissonSource(M, N, rate=1e4, seed=8), 128, max_events=128)))
        swaps.append(pub.publish())
        assert plan.pending == 0, "the scheduled corruption never fired"
        heals = sum(1 for s in swaps if s.kind == "full")
        assert heals >= 1, "corrupt NAK never forced a kind=full heal"
        versions = [r.version for r in fleet.replicas] + [shadow.version]
        assert all(v == pub.version for v in versions), (
            f"fleet diverged after heal: {versions} != v{pub.version}"
        )
        want = jax.tree_util.tree_leaves(shadow.engine.params)
        for r in fleet.replicas:
            got = _leaves(r.state_message())
            assert len(got) == len(want)
            for a, b in zip(got, want):
                assert np.array_equal(np.asarray(a), np.asarray(b)), (
                    f"{r.replica_id} not bitwise-equal to fault-free shadow"
                )
        print(f"  corrupt delta NAKed, healed kind=full, "
              f"fleet bitwise-convergent at v{pub.version}")
        print("chaos-smoke: all gates passed")
        return 0
    finally:
        supervisor.stop()
        fleet.close()
        shadow.close()


if __name__ == "__main__":
    sys.exit(main())
